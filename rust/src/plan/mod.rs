//! The execution-planning subsystem: one resolved plan per run.
//!
//! The paper's central observation is that the *best* execution
//! strategy — block shape, kernel, tile layout, cache sizing — depends
//! on workload geometry and the balance of I/O vs compute. Before this
//! subsystem every knob was threaded by hand through
//! `CoordinatorConfig`, `JobSpec`, and the CLI; now every entry point
//! resolves its inputs into one [`ExecPlan`] up front and consumes only
//! that:
//!
//! ```text
//!   pins (CLI flags / config / caller)          workload geometry
//!                  │                                   │
//!                  ▼                                   ▼
//!            [`PlanRequest`] ──▶ [`Planner`] + [`CostModel`]
//!                                     │
//!                      ┌──────────────┴──────────────┐
//!                      ▼                             ▼
//!                 [`ExecPlan`]                  [`Explain`]
//!            (the one resolved run          (every candidate with
//!             description everything         its predicted cost —
//!             downstream consumes)           `blockms plan` prints it)
//! ```
//!
//! A fully-pinned request resolves to exactly its pins (the planner
//! never overrides an explicit choice); unpinned knobs are chosen by
//! minimizing the [`CostModel`]'s predicted wall time over the
//! candidate grid. Resolution is **deterministic**: candidates are
//! enumerated in a fixed order and ties break toward the earlier
//! candidate, so the same request and priors always yield the same
//! plan. The planner only *selects among* bit-identical kernels and
//! layouts, so auto-planning can never change results — only speed.

mod cost;
mod explain;

pub use cost::{
    sharded_wire_bytes, CostModel, PlanCost, SweepCost, Workload, CALIB_KS,
    DEFAULT_WIRE_NS_PER_BYTE, REF_WORKERS,
};
pub use explain::{Candidate, Explain};

use crate::blocks::{ApproachKind, BlockPlan, BlockShape};
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::simd::SimdMode;
use crate::kmeans::tile::TileLayout;

/// Worker count the planner assumes when nothing pins it.
pub const DEFAULT_WORKERS: usize = 4;

/// Tile-arena budget (MiB) when nothing pins it and the planner has no
/// reason to size it to the workload.
pub const DEFAULT_ARENA_MB: usize = 256;

/// The single resolved description of one run: everything the
/// coordinator, the service, the workers, and the benches need to
/// execute — no `Option`s, no "resolve later".
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecPlan {
    /// Concrete block geometry (already sized, not an approach kind).
    pub shape: BlockShape,
    /// Worker thread count (paper: 2, 4, 8).
    pub workers: usize,
    /// Compute kernel for step/assign rounds — bit-identical results
    /// across all choices (see [`crate::kmeans::kernel`]).
    pub kernel: KernelChoice,
    /// How block pixels are held across rounds (see
    /// [`crate::kmeans::tile`]). Always concrete: construction resolves
    /// "kernel native" immediately.
    pub layout: TileLayout,
    /// Per-worker tile-arena byte budget in MiB (SoA layout).
    pub arena_mb: usize,
    /// Overlap next-block reads with compute (double buffering).
    pub prefetch: bool,
    /// Shared decoded-strip LRU capacity in strips (0 = no cache);
    /// meaningful only under strip I/O.
    pub strip_cache: usize,
    /// Hard resident pixel-byte budget in MiB (0 = unbounded). Carried
    /// on the plan so downstream layers (label sink sizing, the `ran:`
    /// line, benches) see the same number the planner enforced.
    pub mem_mb: usize,
    /// Back the strip store with a real file instead of memory. The
    /// planner's degrade-under-budget axis: file backing trades strip
    /// re-decodes for an image-height-independent resident footprint.
    /// Meaningful only under strip I/O.
    pub file_backed: bool,
    /// Per-block retry budget per round (0 = fail fast, the seed
    /// behaviour). Like `mem_mb`, a carried-through knob rather than a
    /// planner axis: retries never change values (a re-queued block is
    /// a pure function of the shipped centroids), only availability.
    pub retries: usize,
    /// Write a round-boundary checkpoint every N rounds (0 = never).
    /// The destination path rides on the coordinator/service config;
    /// this is the cadence the plan commits to.
    pub checkpoint_every: usize,
    /// Soft wall-clock deadline in milliseconds (0 = none). Enforced at
    /// round boundaries: the run stops with a best-effort checkpoint so
    /// it is resumable, never silently truncated. A carried-through
    /// knob, not a planner axis — it changes when a run *stops*, never
    /// what it computes.
    pub deadline_ms: usize,
    /// QoS priority for service admission (higher wins; 0 = default).
    /// The priority-weighted queue drains higher-priority jobs first
    /// and the admission gate sheds lowest-priority work under
    /// overload. Carried-through only.
    pub priority: usize,
    /// Speculatively re-execute straggling blocks (first completed
    /// result wins — bit-identical by construction, see
    /// [`crate::resilience`]). Carried-through only: speculation costs
    /// duplicate compute, never values.
    pub speculate: bool,
    /// The SIMD dispatch decision for [`KernelChoice::Simd`]: capability
    /// level (host-detected once per run, `BLOCKMS_SIMD`-clamped) plus
    /// the opt-in FMA flag. Carried-through — the planner reads the
    /// level for its per-level cost floor but never searches over it
    /// (the host dictates it). Ignored by every other kernel. The
    /// library default is the portable mode so plans built in tests are
    /// architecture-independent; entry points stamp the detected mode.
    pub simd: SimdMode,
    /// Shard process count for distributed execution (0 = solo, the
    /// in-process pool). When nonzero, `workers` becomes the connection
    /// count *per shard* and every block executes shard-side. A search
    /// axis only when [`PlanRequest::shard_grid`] opens it; the model's
    /// wire terms (see [`CostModel::predict_sharded`]) decide whether
    /// distribution pays. Bit-identity is unconditional — shards change
    /// where blocks run, never what they compute.
    pub shards: usize,
    /// Watchdog heartbeat timeout in milliseconds (0 = keep
    /// [`crate::resilience::DEFAULT_HEARTBEAT_TIMEOUT_MS`]). A
    /// carried-through liveness knob, never a search axis: it changes
    /// when a stall *escalates*, not what runs.
    pub heartbeat_ms: usize,
}

impl Default for ExecPlan {
    /// A neutral pinned plan for direct construction in tests and
    /// examples: square 256-tiles, naive kernel, its native interleaved
    /// layout. Real entry points resolve through [`Planner::resolve`].
    fn default() -> Self {
        ExecPlan::pinned(BlockShape::Square { side: 256 })
    }
}

impl ExecPlan {
    /// A fully-pinned plan with the repo's historical defaults for
    /// everything but the shape. Chain the `with_*` builders to pin the
    /// rest.
    pub fn pinned(shape: BlockShape) -> ExecPlan {
        ExecPlan {
            shape,
            workers: DEFAULT_WORKERS,
            kernel: KernelChoice::Naive,
            layout: KernelChoice::Naive.default_layout(),
            arena_mb: DEFAULT_ARENA_MB,
            prefetch: false,
            strip_cache: 0,
            mem_mb: 0,
            file_backed: false,
            retries: 0,
            checkpoint_every: 0,
            deadline_ms: 0,
            priority: 0,
            speculate: false,
            simd: SimdMode::default(),
            shards: 0,
            heartbeat_ms: 0,
        }
    }

    pub fn with_shape(mut self, shape: BlockShape) -> ExecPlan {
        self.shape = shape;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> ExecPlan {
        assert!(workers > 0, "need at least one worker");
        self.workers = workers;
        self
    }

    /// Pin the kernel; the layout follows to the kernel's native shape
    /// (call [`ExecPlan::with_layout`] *after* this to override).
    pub fn with_kernel(mut self, kernel: KernelChoice) -> ExecPlan {
        self.kernel = kernel;
        self.layout = kernel.default_layout();
        self
    }

    pub fn with_layout(mut self, layout: TileLayout) -> ExecPlan {
        self.layout = layout;
        self
    }

    pub fn with_arena_mb(mut self, arena_mb: usize) -> ExecPlan {
        self.arena_mb = arena_mb;
        self
    }

    pub fn with_prefetch(mut self, prefetch: bool) -> ExecPlan {
        self.prefetch = prefetch;
        self
    }

    pub fn with_strip_cache(mut self, strips: usize) -> ExecPlan {
        self.strip_cache = strips;
        self
    }

    /// Pin a resident pixel-byte budget (MiB; 0 = unbounded).
    pub fn with_mem_mb(mut self, mem_mb: usize) -> ExecPlan {
        self.mem_mb = mem_mb;
        self
    }

    /// Pin the strip-store backing (file vs memory).
    pub fn with_file_backing(mut self, file_backed: bool) -> ExecPlan {
        self.file_backed = file_backed;
        self
    }

    /// Pin the per-block retry budget per round (0 = fail fast).
    pub fn with_retries(mut self, retries: usize) -> ExecPlan {
        self.retries = retries;
        self
    }

    /// Pin the checkpoint cadence in rounds (0 = never checkpoint).
    pub fn with_checkpoint_every(mut self, rounds: usize) -> ExecPlan {
        self.checkpoint_every = rounds;
        self
    }

    /// Pin a soft wall-clock deadline in milliseconds (0 = none).
    pub fn with_deadline_ms(mut self, ms: usize) -> ExecPlan {
        self.deadline_ms = ms;
        self
    }

    /// Pin the QoS admission priority (higher wins; 0 = default).
    pub fn with_priority(mut self, priority: usize) -> ExecPlan {
        self.priority = priority;
        self
    }

    /// Enable speculative re-execution of straggling blocks.
    pub fn with_speculate(mut self, speculate: bool) -> ExecPlan {
        self.speculate = speculate;
        self
    }

    /// Pin the SIMD dispatch mode (level + FMA) the Simd kernel runs at.
    pub fn with_simd(mut self, simd: SimdMode) -> ExecPlan {
        self.simd = simd;
        self
    }

    /// Pin the shard process count (0 = solo in-process pool).
    pub fn with_shards(mut self, shards: usize) -> ExecPlan {
        self.shards = shards;
        self
    }

    /// Pin the watchdog heartbeat timeout in ms (0 = library default).
    pub fn with_heartbeat_ms(mut self, ms: usize) -> ExecPlan {
        self.heartbeat_ms = ms;
        self
    }

    /// The kernel cell for human renderings: plain kernel names, with
    /// the Simd kernel carrying its dispatched level — `simd[avx2]`,
    /// `simd[avx512+fma]` — so predicted-vs-actual reports say which
    /// code path actually executed.
    pub fn kernel_label(&self) -> String {
        match self.kernel {
            KernelChoice::Simd => format!("simd[{}]", self.simd.label()),
            k => k.to_string(),
        }
    }

    /// Per-worker arena budget in bytes.
    pub fn arena_bytes(&self) -> usize {
        self.arena_mb << 20
    }

    /// The resident budget in bytes, `None` when unbounded.
    pub fn mem_budget_bytes(&self) -> Option<u64> {
        (self.mem_mb > 0).then(|| (self.mem_mb as u64) << 20)
    }

    /// Materialize the block tiling for an image (deterministic — the
    /// solo coordinator and the service derive identical plans from
    /// identical specs by construction).
    pub fn block_plan(&self, height: usize, width: usize) -> BlockPlan {
        BlockPlan::new(height, width, self.shape)
    }

    /// Resolved block-grid extent for an image.
    pub fn grid(&self, height: usize, width: usize) -> (usize, usize) {
        self.block_plan(height, width).grid_dims()
    }

    /// One-line human rendering ("what ran"), used by the `blockms
    /// cluster` summary and the explain table.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} · {} · {} · {}w",
            self.shape,
            self.kernel_label(),
            self.layout,
            self.workers
        );
        if self.strip_cache > 0 {
            s.push_str(&format!(" · cache {}", self.strip_cache));
        }
        if self.prefetch {
            s.push_str(" · prefetch");
        }
        if self.file_backed {
            s.push_str(" · file");
        }
        if self.mem_mb > 0 {
            s.push_str(&format!(" · mem {}MiB", self.mem_mb));
        }
        if self.retries > 0 {
            s.push_str(&format!(" · retries {}", self.retries));
        }
        if self.checkpoint_every > 0 {
            s.push_str(&format!(" · ckpt/{}r", self.checkpoint_every));
        }
        if self.deadline_ms > 0 {
            s.push_str(&format!(" · ddl {}ms", self.deadline_ms));
        }
        if self.priority > 0 {
            s.push_str(&format!(" · prio {}", self.priority));
        }
        if self.speculate {
            s.push_str(" · spec");
        }
        if self.shards > 0 {
            s.push_str(&format!(" · shards {}", self.shards));
        }
        if self.heartbeat_ms > 0 {
            s.push_str(&format!(" · hb {}ms", self.heartbeat_ms));
        }
        s
    }
}

/// A planning request: workload geometry plus a pin for every knob the
/// planner may otherwise choose. `None` = the planner decides.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanRequest {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub k: usize,
    /// Expected Lloyd iterations (fixed_iters, or max_iters as bound).
    pub rounds: usize,
    /// Strip height of the I/O model (`None` = direct crops).
    pub strip_rows: Option<usize>,
    pub shape: Option<BlockShape>,
    pub workers: Option<usize>,
    pub kernel: Option<KernelChoice>,
    pub layout: Option<TileLayout>,
    pub arena_mb: Option<usize>,
    pub prefetch: Option<bool>,
    pub strip_cache: Option<usize>,
    /// Hard resident pixel-byte budget in MiB. Unlike the knobs above
    /// this is a *constraint*, not an axis: candidates whose predicted
    /// resident footprint exceeds it are infeasible, and the planner
    /// degrades (file backing, smaller arena) instead of picking them.
    pub mem_mb: Option<usize>,
    /// Strip-store backing pin; `None` under a budget lets the planner
    /// choose (memory when it fits, file when it must), and defaults to
    /// memory otherwise (the pre-streaming behaviour).
    pub file_backed: Option<bool>,
    /// Per-block retry budget to carry onto the plan (`None` = 0).
    /// Like `mem_mb` this is not a search axis — every candidate gets
    /// the same value.
    pub retries: Option<usize>,
    /// Checkpoint cadence in rounds to carry onto the plan (`None` = 0).
    pub checkpoint_every: Option<usize>,
    /// Soft deadline (ms) to carry onto the plan (`None` = none).
    /// Carried-through like `retries` — never a search axis.
    pub deadline_ms: Option<usize>,
    /// QoS priority to carry onto the plan (`None` = 0).
    pub priority: Option<usize>,
    /// Straggler speculation flag to carry onto the plan (`None` = off).
    pub speculate: Option<bool>,
    /// SIMD dispatch mode carried onto every candidate plan — a plain
    /// field, not a pin: the host's capability is a fact of the run,
    /// never a search axis. The default (portable, no FMA) keeps
    /// requests architecture-independent; entry points stamp the
    /// detected, env-clamped mode via [`PlanRequest::with_simd`], and
    /// the planner prices the Simd kernel at this level.
    pub simd: SimdMode,
    /// Shard-count pin (`None` = solo unless [`PlanRequest::shard_grid`]
    /// opens the axis). `Some(0)` pins solo explicitly.
    pub shards: Option<usize>,
    /// Shard counts for `--auto` to search over, always alongside the
    /// implicit solo candidate (0). Empty (the default) keeps the grid
    /// identical to the pre-distributed planner — existing
    /// candidate-count contracts hold unless a caller opts in.
    pub shard_grid: Vec<usize>,
    /// Heartbeat timeout (ms) to carry onto the plan (`None` = library
    /// default). Carried-through like `retries`, never a search axis.
    pub heartbeat_ms: Option<usize>,
}

impl PlanRequest {
    pub fn new(height: usize, width: usize, channels: usize, k: usize) -> PlanRequest {
        PlanRequest {
            height,
            width,
            channels,
            k,
            rounds: crate::kmeans::KMeansConfig::default().max_iters,
            ..Default::default()
        }
    }

    /// The workload geometry slice the cost model consumes.
    pub fn workload(&self) -> Workload {
        Workload {
            height: self.height,
            width: self.width,
            channels: self.channels,
            k: self.k,
            rounds: self.rounds,
            strip_rows: self.strip_rows,
        }
    }

    /// Pin every knob from an existing plan — the resulting request
    /// round-trips through [`Planner::resolve`] unchanged (a tested
    /// property).
    pub fn pin_all(mut self, plan: &ExecPlan) -> PlanRequest {
        self.shape = Some(plan.shape);
        self.workers = Some(plan.workers);
        self.kernel = Some(plan.kernel);
        self.layout = Some(plan.layout);
        self.arena_mb = Some(plan.arena_mb);
        self.prefetch = Some(plan.prefetch);
        self.strip_cache = Some(plan.strip_cache);
        self.mem_mb = (plan.mem_mb > 0).then_some(plan.mem_mb);
        self.file_backed = Some(plan.file_backed);
        self.retries = (plan.retries > 0).then_some(plan.retries);
        self.checkpoint_every = (plan.checkpoint_every > 0).then_some(plan.checkpoint_every);
        self.deadline_ms = (plan.deadline_ms > 0).then_some(plan.deadline_ms);
        self.priority = (plan.priority > 0).then_some(plan.priority);
        self.speculate = plan.speculate.then_some(true);
        self.simd = plan.simd;
        self.shards = (plan.shards > 0).then_some(plan.shards);
        self.heartbeat_ms = (plan.heartbeat_ms > 0).then_some(plan.heartbeat_ms);
        self
    }

    pub fn with_rounds(mut self, rounds: usize) -> PlanRequest {
        self.rounds = rounds.max(1);
        self
    }

    pub fn with_strip_rows(mut self, strip_rows: Option<usize>) -> PlanRequest {
        self.strip_rows = strip_rows;
        self
    }

    /// Constrain every candidate to `mem_mb` MiB of resident pixel
    /// bytes (`None` = unbounded).
    pub fn with_mem_mb(mut self, mem_mb: Option<usize>) -> PlanRequest {
        self.mem_mb = mem_mb.filter(|&m| m > 0);
        self
    }

    /// Carry a per-block retry budget onto every candidate plan.
    pub fn with_retries(mut self, retries: Option<usize>) -> PlanRequest {
        self.retries = retries.filter(|&r| r > 0);
        self
    }

    /// Carry a checkpoint cadence (rounds) onto every candidate plan.
    pub fn with_checkpoint_every(mut self, rounds: Option<usize>) -> PlanRequest {
        self.checkpoint_every = rounds.filter(|&r| r > 0);
        self
    }

    /// Carry a soft deadline (ms) onto every candidate plan.
    pub fn with_deadline_ms(mut self, ms: Option<usize>) -> PlanRequest {
        self.deadline_ms = ms.filter(|&m| m > 0);
        self
    }

    /// Carry a QoS priority onto every candidate plan.
    pub fn with_priority(mut self, priority: Option<usize>) -> PlanRequest {
        self.priority = priority.filter(|&p| p > 0);
        self
    }

    /// Carry the straggler-speculation flag onto every candidate plan.
    pub fn with_speculate(mut self, speculate: bool) -> PlanRequest {
        self.speculate = speculate.then_some(true);
        self
    }

    /// Carry the resolved SIMD dispatch mode onto every candidate plan
    /// (and into the cost model's per-level Simd floor).
    pub fn with_simd(mut self, simd: SimdMode) -> PlanRequest {
        self.simd = simd;
        self
    }

    /// Pin the shard count (`None` leaves the axis to `shard_grid`;
    /// `Some(0)` pins solo).
    pub fn with_shards(mut self, shards: Option<usize>) -> PlanRequest {
        self.shards = shards;
        self
    }

    /// Open the shard axis: `--auto` searches these counts against the
    /// implicit solo candidate. Zeros and duplicates are dropped.
    pub fn with_shard_grid(mut self, grid: Vec<usize>) -> PlanRequest {
        let mut g: Vec<usize> = grid.into_iter().filter(|&s| s > 0).collect();
        g.sort_unstable();
        g.dedup();
        self.shard_grid = g;
        self
    }

    /// Carry a heartbeat timeout (ms) onto every candidate plan.
    pub fn with_heartbeat_ms(mut self, ms: Option<usize>) -> PlanRequest {
        self.heartbeat_ms = ms.filter(|&m| m > 0);
        self
    }

    /// True when every knob is pinned (the planner has nothing to do).
    pub fn fully_pinned(&self) -> bool {
        self.shape.is_some()
            && self.workers.is_some()
            && self.kernel.is_some()
            && self.layout.is_some()
            && self.arena_mb.is_some()
            && self.prefetch.is_some()
            && self.strip_cache.is_some()
            && self.file_backed.is_some()
    }
}

/// The planner: candidate enumeration + cost-model argmin. See module
/// docs for the determinism and never-override-a-pin contracts.
#[derive(Clone, Debug, Default)]
pub struct Planner {
    model: CostModel,
}

impl Planner {
    pub fn new(model: CostModel) -> Planner {
        Planner { model }
    }

    pub fn model(&self) -> &CostModel {
        &self.model
    }

    pub fn model_mut(&mut self) -> &mut CostModel {
        &mut self.model
    }

    /// Every candidate the request admits, in the fixed enumeration
    /// order (shapes, then kernels, then layouts, then cache, then
    /// prefetch), each with its predicted cost. Pins collapse an axis
    /// to the pinned value.
    pub fn candidates(&self, req: &PlanRequest) -> Vec<Candidate> {
        assert!(
            req.height > 0 && req.width > 0 && req.channels > 0 && req.k > 0,
            "degenerate plan request {}x{} c={} k={}",
            req.height,
            req.width,
            req.channels,
            req.k
        );
        let w = req.workload();
        let shapes: Vec<BlockShape> = match req.shape {
            Some(s) => vec![s],
            None => {
                let mut v: Vec<BlockShape> = ApproachKind::ALL
                    .iter()
                    .map(|&a| BlockShape::paper_default(a, req.height, req.width))
                    .collect();
                // The paper's ~5-block shapes keep ~1/5 of the image in
                // each worker's crop buffer — often the whole budget by
                // itself. Under a constraint, also offer the natural
                // streaming tile: row bands one strip tall, whose
                // resident footprint is strip-sized and independent of
                // image height.
                if let (Some(rows), Some(_)) = (req.strip_rows, req.mem_mb) {
                    let streaming = BlockShape::Rows {
                        band_rows: rows.max(1),
                    };
                    if !v.contains(&streaming) {
                        v.push(streaming);
                    }
                }
                v
            }
        };
        let kernels: Vec<KernelChoice> = match req.kernel {
            Some(k) => vec![k],
            None => KernelChoice::ALL.to_vec(),
        };
        let layouts: Vec<TileLayout> = match req.layout {
            Some(l) => vec![l],
            None => vec![TileLayout::Interleaved, TileLayout::Soa],
        };
        let caches: Vec<usize> = match req.strip_cache {
            Some(c) => vec![c],
            // A cache only matters when strips can be re-decoded.
            None if req.strip_rows.is_some() => vec![0, w.unique_strips()],
            None => vec![0],
        };
        let prefetches: Vec<bool> = match req.prefetch {
            Some(p) => vec![p],
            None if req.strip_rows.is_some() => vec![false, true],
            None => vec![false],
        };
        // The backing axis only opens up when a budget makes it matter:
        // memory backing is never slower, so without a constraint the
        // extra candidates would all lose. Memory enumerates first, so
        // cost ties degrade toward the pre-streaming behaviour.
        let backings: Vec<bool> = match req.file_backed {
            Some(b) => vec![b],
            None if req.strip_rows.is_some() && req.mem_mb.is_some() => vec![false, true],
            None => vec![false],
        };
        // The shard axis stays closed (solo only) unless a pin or an
        // explicit grid opens it — existing candidate-count contracts
        // hold for every caller that never mentions shards. Solo (0)
        // enumerates first so cost ties never distribute.
        let shard_counts: Vec<usize> = match req.shards {
            Some(s) => vec![s],
            None if !req.shard_grid.is_empty() => {
                let mut v = vec![0];
                v.extend(req.shard_grid.iter().copied());
                v
            }
            None => vec![0],
        };
        let workers = req.workers.unwrap_or(DEFAULT_WORKERS);
        let arena_mb = req
            .arena_mb
            .unwrap_or_else(|| self.auto_arena_mb(&w, workers, req.mem_mb));
        let mem_budget = req.mem_mb.map(|m| (m as u64) << 20);
        // Price the Simd kernel at the run's dispatched level (portable
        // scale = 1.0 ties Lanes, so an un-stamped request never
        // prefers Simd over the portable code it would degrade to).
        let mut model = self.model.clone();
        model.simd_level = req.simd.level;

        let mut out = Vec::new();
        for &shape in &shapes {
            let plan = BlockPlan::new(req.height, req.width, shape);
            for &kernel in &kernels {
                for &layout in &layouts {
                    for &strip_cache in &caches {
                        for &prefetch in &prefetches {
                            for &file_backed in &backings {
                                for &shards in &shard_counts {
                                    let cost = model.predict_sharded(
                                        &w,
                                        &plan,
                                        kernel,
                                        layout,
                                        workers,
                                        strip_cache,
                                        prefetch,
                                        shards,
                                    );
                                    let resident_bytes = model.resident_bytes(
                                        &w,
                                        &plan,
                                        kernel,
                                        layout,
                                        workers,
                                        strip_cache,
                                        prefetch,
                                        arena_mb,
                                        file_backed,
                                        mem_budget,
                                    );
                                    let feasible = mem_budget.map_or(true, |b| resident_bytes <= b);
                                    out.push(Candidate {
                                        plan: ExecPlan {
                                            shape,
                                            workers,
                                            kernel,
                                            layout,
                                            arena_mb,
                                            prefetch,
                                            strip_cache,
                                            mem_mb: req.mem_mb.unwrap_or(0),
                                            file_backed,
                                            retries: req.retries.unwrap_or(0),
                                            checkpoint_every: req.checkpoint_every.unwrap_or(0),
                                            deadline_ms: req.deadline_ms.unwrap_or(0),
                                            priority: req.priority.unwrap_or(0),
                                            speculate: req.speculate.unwrap_or(false),
                                            simd: req.simd,
                                            shards,
                                            heartbeat_ms: req.heartbeat_ms.unwrap_or(0),
                                        },
                                        blocks: plan.len(),
                                        grid: plan.grid_dims(),
                                        cost,
                                        resident_bytes,
                                        feasible,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Resolve a request into the one plan to run, plus the explain
    /// report over everything that was considered.
    ///
    /// Under a `mem_mb` constraint the argmin runs over *feasible*
    /// candidates only — the planner degrades to file backing and a
    /// smaller arena instead of picking an OOM plan. When nothing fits
    /// (budget below even the streamed floor), the smallest-footprint
    /// candidate is returned and [`Explain::budget_exceeded`] is set so
    /// entry points can fail with the shortfall instead of thrashing.
    pub fn resolve(&self, req: &PlanRequest) -> (ExecPlan, Explain) {
        let candidates = self.candidates(req);
        // Deterministic argmin: strictly-less keeps the earliest of a
        // tie, and enumeration order is fixed.
        let mut best: Option<usize> = None;
        for (i, c) in candidates.iter().enumerate() {
            if !c.feasible {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) if c.cost.wall_secs < candidates[b].cost.wall_secs => best = Some(i),
                Some(_) => {}
            }
        }
        let best = best.unwrap_or_else(|| {
            let mut b = 0usize;
            for (i, c) in candidates.iter().enumerate() {
                if c.resident_bytes < candidates[b].resident_bytes {
                    b = i;
                }
            }
            b
        });
        let plan = candidates[best].plan;
        let explain = Explain::new(req.clone(), candidates, best, self.model.error_bound);
        (plan, explain)
    }

    /// Arena sizing when unpinned: big enough that every SoA tile of
    /// the job fits its worker's share with deinterleave padding slack,
    /// floored at the historical default — but under a `mem_mb` budget
    /// the arena gets at most half the budget split across workers
    /// (tiles degrade to spilled re-reads, never to an OOM).
    fn auto_arena_mb(&self, w: &Workload, workers: usize, mem_mb: Option<usize>) -> usize {
        let per_worker = (w.image_bytes() as usize * 5 / 4) / workers.max(1);
        let auto = DEFAULT_ARENA_MB.max(per_worker.div_ceil(1 << 20));
        match mem_mb {
            Some(m) => auto.min((m / 2) / workers.max(1)),
            None => auto,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> PlanRequest {
        PlanRequest::new(1024, 1024, 3, 4)
            .with_rounds(4)
            .with_strip_rows(Some(64))
    }

    #[test]
    fn fully_pinned_request_round_trips() {
        let pinned = ExecPlan::pinned(BlockShape::Cols { band_cols: 205 })
            .with_workers(2)
            .with_kernel(KernelChoice::Pruned)
            .with_layout(TileLayout::Soa)
            .with_arena_mb(64)
            .with_prefetch(true)
            .with_strip_cache(7);
        let r = req().pin_all(&pinned);
        assert!(r.fully_pinned());
        let (resolved, explain) = Planner::default().resolve(&r);
        assert_eq!(resolved, pinned);
        assert_eq!(explain.candidates.len(), 1);
    }

    #[test]
    fn auto_explores_the_full_grid() {
        let (plan, explain) = Planner::default().resolve(&req());
        // 3 shapes x 5 kernels x 2 layouts x 2 caches x 2 prefetch
        assert_eq!(explain.candidates.len(), 120);
        // the model's lanes floors dominate: auto must not pick naive.
        // (The request is un-stamped, so Simd prices at the portable
        // scale of 1.0 — a tie Lanes wins by enumeration order.)
        assert_eq!(plan.kernel, KernelChoice::Lanes);
        // picked plan is the explain's chosen row
        assert_eq!(explain.chosen().plan, plan);
    }

    #[test]
    fn auto_picks_simd_when_the_stamped_level_beats_lanes() {
        use crate::kmeans::simd::{SimdLevel, SimdMode};
        // A native level with a sub-1.0 measured scale must win the
        // argmin; portable (scale 1.0) must leave Lanes the winner.
        let r = req().with_simd(SimdMode {
            level: SimdLevel::Avx2,
            fma: false,
        });
        let (plan, explain) = Planner::default().resolve(&r);
        assert_eq!(plan.kernel, KernelChoice::Simd, "{}", plan.summary());
        assert_eq!(plan.simd.level, SimdLevel::Avx2);
        assert_eq!(plan.layout, TileLayout::Soa);
        assert!(explain.candidates.iter().all(|c| c.plan.simd.level == SimdLevel::Avx2));
        assert!(plan.summary().contains("simd[avx2]"), "{}", plan.summary());
    }

    #[test]
    fn pick_is_no_regret_under_its_own_model() {
        let planner = Planner::default();
        for k in [1, 2, 3, 5, 8, 13] {
            let mut r = req();
            r.k = k;
            let (plan, explain) = planner.resolve(&r);
            let chosen = explain.chosen();
            assert_eq!(chosen.plan, plan);
            for c in &explain.candidates {
                assert!(
                    chosen.cost.wall_secs <= c.cost.wall_secs,
                    "k={k}: picked {:?} but {:?} predicts cheaper",
                    chosen.plan,
                    c.plan
                );
            }
        }
    }

    #[test]
    fn resolution_is_deterministic() {
        let planner = Planner::default();
        let (a, ea) = planner.resolve(&req());
        let (b, eb) = planner.resolve(&req());
        assert_eq!(a, b);
        assert_eq!(
            ea.candidates.iter().map(|c| c.plan).collect::<Vec<_>>(),
            eb.candidates.iter().map(|c| c.plan).collect::<Vec<_>>()
        );
    }

    #[test]
    fn pins_constrain_the_search() {
        let planner = Planner::default();
        let mut r = req();
        r.kernel = Some(KernelChoice::Naive);
        r.prefetch = Some(false);
        let (plan, explain) = planner.resolve(&r);
        assert_eq!(plan.kernel, KernelChoice::Naive);
        assert!(!plan.prefetch);
        assert!(explain.candidates.iter().all(|c| c.plan.kernel == KernelChoice::Naive));
        // 3 shapes x 1 kernel x 2 layouts x 2 caches x 1 prefetch
        assert_eq!(explain.candidates.len(), 12);
        // the portable default mode rides along un-searched
        assert_eq!(plan.simd, SimdMode::default());
    }

    #[test]
    fn direct_io_skips_cache_and_prefetch_axes() {
        let planner = Planner::default();
        let r = PlanRequest::new(512, 512, 3, 2).with_rounds(3);
        let (plan, explain) = planner.resolve(&r);
        assert_eq!(plan.strip_cache, 0);
        assert!(!plan.prefetch);
        // 3 shapes x 5 kernels x 2 layouts
        assert_eq!(explain.candidates.len(), 30);
    }

    #[test]
    fn auto_arena_scales_with_image() {
        let planner = Planner::default();
        let small = PlanRequest::new(256, 256, 3, 2);
        let (p_small, _) = planner.resolve(&small);
        assert_eq!(p_small.arena_mb, DEFAULT_ARENA_MB);
        let huge = PlanRequest::new(16384, 16384, 3, 2);
        let (p_huge, _) = planner.resolve(&huge);
        // 16384^2 x 3 x 4 bytes x 1.25 / 4 workers = 960 MiB
        assert!(p_huge.arena_mb > DEFAULT_ARENA_MB, "{}", p_huge.arena_mb);
    }

    #[test]
    fn budget_degrades_to_file_backing_instead_of_oom() {
        // 1024x1024x3 f32 = 12 MiB of pixels; an 8 MiB budget cannot
        // hold the image, so a memory-backed store is infeasible and
        // the planner must degrade: file backing, strip-sized row
        // blocks, interleaved reads, arena capped under the budget.
        let r = req().with_mem_mb(Some(8));
        let (plan, explain) = Planner::default().resolve(&r);
        assert!(!explain.budget_exceeded(), "{}", plan.summary());
        assert!(plan.file_backed, "must degrade to file backing");
        assert_eq!(plan.mem_mb, 8);
        assert!(explain.chosen().feasible);
        assert!(explain.chosen().resident_bytes <= 8 << 20);
        assert_eq!(plan.layout, TileLayout::Interleaved, "arena would blow the budget");
        assert!(plan.arena_mb <= 1, "arena {} not capped", plan.arena_mb);
        // Unconstrained resolve keeps the pre-streaming behaviour.
        let (free, e) = Planner::default().resolve(&req());
        assert!(!free.file_backed);
        assert_eq!(free.mem_mb, 0);
        assert!(e.candidates.iter().all(|c| c.feasible));
    }

    #[test]
    fn feasible_candidates_beat_cheaper_infeasible_ones() {
        let r = req().with_mem_mb(Some(8));
        let (_, explain) = Planner::default().resolve(&r);
        let chosen = explain.chosen();
        for c in &explain.candidates {
            if c.feasible {
                assert!(
                    chosen.cost.wall_secs <= c.cost.wall_secs,
                    "picked {:?} but feasible {:?} predicts cheaper",
                    chosen.plan,
                    c.plan
                );
            }
        }
        // at least one cheaper-but-infeasible candidate exists (the
        // memory-backed lanes plans the unconstrained resolve prefers)
        assert!(
            explain.candidates.iter().any(|c| !c.feasible),
            "budget did not constrain anything"
        );
    }

    #[test]
    fn impossible_budget_is_reported_not_thrashed() {
        let r = req().with_mem_mb(Some(1));
        let (plan, explain) = Planner::default().resolve(&r);
        assert!(explain.budget_exceeded());
        // the fallback is still the smallest-footprint candidate
        for c in &explain.candidates {
            assert!(explain.chosen().resident_bytes <= c.resident_bytes);
        }
        assert_eq!(plan, explain.chosen().plan);
    }

    #[test]
    fn resilience_knobs_ride_through_without_widening_the_search() {
        let planner = Planner::default();
        let r = req()
            .with_retries(Some(2))
            .with_checkpoint_every(Some(5))
            .with_deadline_ms(Some(30_000))
            .with_priority(Some(7))
            .with_speculate(true);
        let (plan, explain) = planner.resolve(&r);
        assert_eq!(plan.retries, 2);
        assert_eq!(plan.checkpoint_every, 5);
        assert_eq!(plan.deadline_ms, 30_000);
        assert_eq!(plan.priority, 7);
        assert!(plan.speculate);
        // carried-through, not an axis: same grid as the plain request
        assert_eq!(explain.candidates.len(), Planner::default().resolve(&req()).1.candidates.len());
        assert!(explain.candidates.iter().all(|c| c.plan.retries == 2
            && c.plan.checkpoint_every == 5
            && c.plan.deadline_ms == 30_000
            && c.plan.priority == 7
            && c.plan.speculate));
        // and pin_all round-trips them
        let rt = req().pin_all(&plan);
        let (again, _) = planner.resolve(&rt);
        assert_eq!(again, plan);
    }

    #[test]
    fn shard_axis_opens_only_on_request() {
        let planner = Planner::default();
        // Closed by default: the grid (and every count contract above)
        // is exactly the pre-distributed planner's.
        let (plan, closed) = planner.resolve(&req());
        assert_eq!(plan.shards, 0);
        assert!(closed.candidates.iter().all(|c| c.plan.shards == 0));
        // An explicit grid triples the cells: solo + {2, 4} each
        // (zeros and duplicates in the caller's list drop out).
        let r = req().with_shard_grid(vec![4, 2, 2, 0]);
        let (_, open) = planner.resolve(&r);
        assert_eq!(open.candidates.len(), 3 * closed.candidates.len());
        // A pin collapses the axis to one value, like every other knob.
        let r = req().with_shards(Some(2));
        let (pinned, e) = planner.resolve(&r);
        assert_eq!(pinned.shards, 2);
        assert_eq!(e.candidates.len(), closed.candidates.len());
        assert!(e.candidates.iter().all(|c| c.plan.shards == 2));
        assert!(pinned.summary().contains("shards 2"), "{}", pinned.summary());
    }

    #[test]
    fn auto_distributes_only_when_the_freight_pays() {
        let planner = Planner::default();
        // Big workload, many rounds, lanes to spare: the model's saved
        // compute dwarfs the closed-form wire freight.
        let big = PlanRequest::new(8192, 8192, 3, 8)
            .with_rounds(30)
            .with_shard_grid(vec![2, 4]);
        let (plan, explain) = planner.resolve(&big);
        assert!(plan.shards > 0, "{}", plan.summary());
        // No regret under its own model, shard candidates included.
        for c in &explain.candidates {
            assert!(explain.chosen().cost.wall_secs <= c.cost.wall_secs);
        }
        // Tiny workload with workers already saturating the block
        // count: distribution cannot save compute, so solo must win.
        let tiny = PlanRequest::new(128, 128, 3, 2)
            .with_rounds(2)
            .with_shard_grid(vec![2, 4]);
        let tiny = PlanRequest {
            workers: Some(8),
            ..tiny
        };
        let (plan, _) = planner.resolve(&tiny);
        assert_eq!(plan.shards, 0, "{}", plan.summary());
    }

    #[test]
    fn distributed_knobs_ride_through_and_round_trip() {
        let planner = Planner::default();
        let r = req().with_shards(Some(3)).with_heartbeat_ms(Some(250));
        let (plan, explain) = planner.resolve(&r);
        assert_eq!(plan.shards, 3);
        assert_eq!(plan.heartbeat_ms, 250);
        assert!(explain.candidates.iter().all(|c| c.plan.heartbeat_ms == 250));
        let rt = req().pin_all(&plan);
        let (again, _) = planner.resolve(&rt);
        assert_eq!(again, plan);
        let s = plan.summary();
        assert!(s.contains("shards 3") && s.contains("hb 250ms"), "{s}");
    }

    #[test]
    fn hardening_knobs_show_in_the_summary() {
        let s = ExecPlan::default()
            .with_deadline_ms(1500)
            .with_priority(3)
            .with_speculate(true)
            .summary();
        for part in ["ddl 1500ms", "prio 3", "spec"] {
            assert!(s.contains(part), "{part} missing from {s:?}");
        }
    }

    #[test]
    fn with_kernel_follows_native_layout_then_override() {
        let p = ExecPlan::default().with_kernel(KernelChoice::Lanes);
        assert_eq!(p.layout, TileLayout::Soa);
        let p = p.with_layout(TileLayout::Interleaved);
        assert_eq!(p.layout, TileLayout::Interleaved);
        assert_eq!(p.kernel, KernelChoice::Lanes);
    }

    #[test]
    fn summary_names_the_strategy() {
        let s = ExecPlan::pinned(BlockShape::Square { side: 459 })
            .with_kernel(KernelChoice::Lanes)
            .with_strip_cache(16)
            .with_prefetch(true)
            .summary();
        for part in ["square[459 459]", "lanes", "soa", "4w", "cache 16", "prefetch"] {
            assert!(s.contains(part), "{part} missing from {s:?}");
        }
    }
}
