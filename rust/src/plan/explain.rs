//! The explain report: every candidate the planner considered, ranked.
//!
//! `blockms plan` prints this table; `blockms cluster --auto --dry-run`
//! prints the chosen row plus the rationale. The report is data first —
//! [`Explain::ranked`] is what `bench/plan.rs` records into
//! `BENCH_plan.json` — and rendering second.

use super::cost::PlanCost;
use super::{ExecPlan, PlanRequest};
use crate::util::fmt::Table;

/// One candidate execution strategy with its predicted cost.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub plan: ExecPlan,
    /// Blocks the shape yields on the requested image.
    pub blocks: usize,
    /// Block-grid extent `(grid_rows, grid_cols)`.
    pub grid: (usize, usize),
    pub cost: PlanCost,
    /// Predicted peak resident pixel bytes
    /// ([`super::CostModel::resident_bytes`]).
    pub resident_bytes: u64,
    /// Fits the request's `mem_mb` budget (always true when unbounded).
    pub feasible: bool,
}

/// The full report of one [`super::Planner::resolve`] call.
#[derive(Clone, Debug)]
pub struct Explain {
    pub request: PlanRequest,
    /// Candidates in enumeration order (deterministic).
    pub candidates: Vec<Candidate>,
    /// Index of the chosen candidate in `candidates`.
    pub chosen: usize,
    /// The cost model's stated relative prediction-error bound.
    pub error_bound: f64,
}

impl Explain {
    pub(super) fn new(
        request: PlanRequest,
        candidates: Vec<Candidate>,
        chosen: usize,
        error_bound: f64,
    ) -> Explain {
        assert!(chosen < candidates.len(), "chosen candidate out of range");
        Explain {
            request,
            candidates,
            chosen,
            error_bound,
        }
    }

    pub fn chosen(&self) -> &Candidate {
        &self.candidates[self.chosen]
    }

    /// True when the request's `mem_mb` budget admits no candidate at
    /// all — the chosen plan is then merely the smallest-footprint
    /// fallback and entry points should refuse to run it.
    pub fn budget_exceeded(&self) -> bool {
        !self.chosen().feasible
    }

    /// Candidates sorted by predicted wall time (stable: prediction
    /// ties keep enumeration order). The chosen candidate is always
    /// `ranked()[0]` — the no-regret invariant the property suite
    /// checks.
    pub fn ranked(&self) -> Vec<&Candidate> {
        let mut v: Vec<&Candidate> = self.candidates.iter().collect();
        v.sort_by(|a, b| {
            a.cost
                .wall_secs
                .partial_cmp(&b.cost.wall_secs)
                .expect("predicted costs are finite")
        });
        v
    }

    /// Predicted slowdown of a candidate vs the chosen plan (1.0 for
    /// the pick itself).
    pub fn predicted_slowdown(&self, c: &Candidate) -> f64 {
        c.cost.wall_secs / self.chosen().cost.wall_secs
    }

    /// One line of planner rationale for the chosen plan.
    pub fn rationale(&self) -> String {
        let c = self.chosen();
        let io = if c.cost.io_secs > 0.0 {
            format!(
                ", {:.1} MiB strip decode",
                c.cost.decode_bytes as f64 / (1 << 20) as f64
            )
        } else {
            String::new()
        };
        let mem = if self.request.mem_mb.is_some() {
            let infeasible = self.candidates.iter().filter(|c| !c.feasible).count();
            format!(
                "; predicted peak resident {:.1} MiB ({infeasible} candidates over budget)",
                c.resident_bytes as f64 / (1 << 20) as f64
            )
        } else {
            String::new()
        };
        format!(
            "picked {} over {} candidates: predicted {:.2} ns/px/pass \
             ({:.0}% compute{io}); model error bound ±{:.0}%{mem}",
            c.plan.summary(),
            self.candidates.len(),
            c.cost.ns_per_pixel_pass,
            100.0 * c.cost.compute_secs / c.cost.wall_secs.max(f64::MIN_POSITIVE),
            100.0 * self.error_bound,
        )
    }

    /// The explain table `blockms plan` prints: every candidate ranked
    /// by predicted cost, the chosen row marked.
    pub fn render(&self, top: usize) -> String {
        let ranked = self.ranked();
        let shown = ranked.len().min(top.max(1));
        let mut t = Table::new(format!(
            "Plan candidates for {}x{} c={} k={} rounds={} ({} shown of {}, model ±{:.0}%)",
            self.request.width,
            self.request.height,
            self.request.channels,
            self.request.k,
            self.request.rounds,
            shown,
            ranked.len(),
            100.0 * self.error_bound,
        ))
        .header(&[
            "", "Shape", "Grid", "Kernel", "Layout", "Cache", "Pf", "Store", "Res MiB",
            "ns/px/pass", "Pred wall", "vs pick",
        ]);
        for c in ranked.iter().take(shown) {
            let pick = std::ptr::eq(*c, self.chosen());
            t.row(vec![
                match (pick, c.feasible) {
                    (true, _) => "*".to_string(),
                    (false, false) => "!".to_string(),
                    (false, true) => String::new(),
                },
                c.plan.shape.to_string(),
                format!("{}x{}", c.grid.0, c.grid.1),
                c.plan.kernel_label(),
                c.plan.layout.to_string(),
                c.plan.strip_cache.to_string(),
                if c.plan.prefetch { "y" } else { "-" }.to_string(),
                if c.plan.file_backed { "file" } else { "mem" }.to_string(),
                format!("{:.1}", c.resident_bytes as f64 / (1 << 20) as f64),
                format!("{:.2}", c.cost.ns_per_pixel_pass),
                crate::util::fmt::duration(c.cost.wall_secs),
                format!("{:.2}x", self.predicted_slowdown(c)),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{PlanRequest, Planner};

    fn explain() -> super::Explain {
        let req = PlanRequest::new(512, 512, 3, 4)
            .with_rounds(3)
            .with_strip_rows(Some(32));
        Planner::default().resolve(&req).1
    }

    #[test]
    fn ranked_puts_the_pick_first() {
        let e = explain();
        let ranked = e.ranked();
        assert_eq!(ranked.len(), e.candidates.len());
        assert!(std::ptr::eq(ranked[0], e.chosen()));
        for w in ranked.windows(2) {
            assert!(w[0].cost.wall_secs <= w[1].cost.wall_secs);
        }
    }

    #[test]
    fn render_marks_the_pick_and_truncates() {
        let e = explain();
        let text = e.render(5);
        assert!(text.contains('*'), "{text}");
        assert!(text.contains("5 shown of"), "{text}");
        assert!(text.contains("ns/px/pass"), "{text}");
    }

    #[test]
    fn rationale_names_candidate_count() {
        let e = explain();
        let r = e.rationale();
        assert!(r.contains(&format!("over {} candidates", e.candidates.len())), "{r}");
    }
}
