//! The planner's analytic cost model.
//!
//! Predicts wall-clock for a candidate [`crate::plan::ExecPlan`] from
//! workload geometry and two families of priors:
//!
//! - **compute floors** — ns per pixel per pass for every
//!   (kernel, layout) pair at the calibration cluster counts
//!   (k ∈ {2, 4, 8}), taken from the committed `BENCH_layout.json`
//!   row-shaped cells (amplification 1.0: the closest the matrix gets
//!   to pure compute). Piecewise-linear in `k` between calibration
//!   points, clamped at the ends.
//! - **decode cost** — wall nanoseconds per *excess* byte read beyond
//!   one clean pass of the image per fill pass, least-squares fit over
//!   the naive column/square cells of the same matrix (pruned/lanes
//!   cells are excluded from the fit: their shape sensitivity is
//!   pruning efficacy, not I/O).
//!
//! The model is deliberately coarse — it ranks execution strategies, it
//! does not simulate them ([`crate::simtime`] does that). Its honesty
//! contract is [`CostModel::error_bound`]: the largest relative
//! prediction error observed against the calibration matrix itself
//! (dominated by shape-dependent pruning efficacy, which no static
//! model can see). `BENCH_plan.json` records that planner *regret* —
//! the paper-relevant number — stays far inside that bound.
//!
//! Priors are refinable at runtime: [`CostModel::calibrate_from_json`]
//! re-derives them from any `BENCH_layout.json`-shaped document, and
//! [`CostModel::refine`] blends in per-run observations (`BlockCost`
//! compute totals or `simtime` replays reduced to observed ns/px/pass).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::blocks::BlockPlan;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::simd::SimdLevel;
use crate::kmeans::tile::TileLayout;
use crate::util::json::Json;

/// Worker count the priors were measured at. Predictions for other
/// worker counts scale from this reference.
pub const REF_WORKERS: usize = 4;

/// Default wall ns per wire byte when no calibration has run — a
/// loopback/UDS-order figure (~6.7 GB/s effective through serialize +
/// copy + wakeup), deliberately pessimistic for same-host transports so
/// `--auto` only goes distributed when compute savings clearly dominate.
pub const DEFAULT_WIRE_NS_PER_BYTE: f64 = 0.15;

/// Calibration cluster counts of the committed layout matrix.
pub const CALIB_KS: [usize; 3] = [2, 4, 8];

/// Fused has no committed calibration row (the layout matrix sweeps
/// naive/pruned/lanes); its prior is the pruned floor scaled by this —
/// fused shares pruned's step rounds and saves most of one full-scan
/// labeling pass out of `iters + 1`.
const FUSED_OVER_PRUNED: f64 = 0.96;

/// Per-[`SimdLevel`] simd-over-lanes wall ratios. Like fused, the Simd
/// kernel has no committed calibration row of its own: it shares the
/// lanes floor scaled by the ratio of its dispatched level. The defaults
/// are conservative priors for the distance kernel (wider vectors help
/// until memory bandwidth does not); the startup microbench
/// ([`CostModel::calibrate_simd`]) replaces the dispatched level's
/// entry with a *measured* ratio, so `--auto` picks Simd only where it
/// is measured faster on the actual host. Portable is exactly 1.0 by
/// construction — it runs the identical lanes inner loop — which makes
/// un-stamped plans tie (and lose, by enumeration order) against Lanes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimdScale {
    pub avx512: f64,
    pub avx2: f64,
    pub neon: f64,
    pub portable: f64,
}

impl Default for SimdScale {
    fn default() -> Self {
        SimdScale {
            avx512: 0.58,
            avx2: 0.72,
            neon: 0.82,
            portable: 1.0,
        }
    }
}

impl SimdScale {
    pub fn get(&self, level: SimdLevel) -> f64 {
        match level {
            SimdLevel::Avx512 => self.avx512,
            SimdLevel::Avx2 => self.avx2,
            SimdLevel::Neon => self.neon,
            SimdLevel::Portable => self.portable,
        }
    }

    pub fn set(&mut self, level: SimdLevel, ratio: f64) {
        match level {
            SimdLevel::Avx512 => self.avx512 = ratio,
            SimdLevel::Avx2 => self.avx2 = ratio,
            SimdLevel::Neon => self.neon = ratio,
            SimdLevel::Portable => self.portable = ratio,
        }
    }
}

/// Workload geometry the model predicts against — everything about the
/// run that is *not* an execution-strategy choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Workload {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub k: usize,
    /// Expected Lloyd iterations (`fixed_iters`, or `max_iters` as the
    /// pessimistic bound); total passes over the image are `rounds + 1`.
    pub rounds: usize,
    /// Strip height of the I/O model; `None` = direct in-memory crops
    /// (no strip store, no decode cost, nothing for a cache to do).
    pub strip_rows: Option<usize>,
}

impl Workload {
    pub fn pixels(&self) -> usize {
        self.height * self.width
    }

    pub fn passes(&self) -> usize {
        self.rounds + 1
    }

    /// One clean pass worth of image bytes (f32 samples).
    pub fn image_bytes(&self) -> u64 {
        (self.pixels() * self.channels * 4) as u64
    }

    /// Strips the store would hold for this workload.
    pub fn unique_strips(&self) -> usize {
        match self.strip_rows {
            Some(rows) => self.height.div_ceil(rows.max(1)),
            None => 0,
        }
    }
}

/// Predicted cost breakdown for one candidate plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanCost {
    /// Predicted wall seconds for the whole run (compute + excess I/O,
    /// overlapped when the candidate prefetches).
    pub wall_secs: f64,
    /// Predicted wall ns per pixel per pass (the bench-comparable unit).
    pub ns_per_pixel_pass: f64,
    /// Compute share of the wall (seconds).
    pub compute_secs: f64,
    /// Excess-decode share of the wall (seconds).
    pub io_secs: f64,
    /// Total strip bytes the candidate transfers (0 for direct I/O).
    pub decode_bytes: u64,
    /// Strip reads that actually decode (cache misses), whole run.
    pub strip_transfers: u64,
}

/// Predicted cost of a multi-variant sweep over one image, both ways
/// of running it (see [`CostModel::predict_sweep`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SweepCost {
    /// One share group: tiles keyed by content, the image's strips
    /// decode once for the whole sweep.
    pub amortized: PlanCost,
    /// Each variant isolated: N variants pay N full I/O passes.
    pub serialized: PlanCost,
}

impl SweepCost {
    /// Predicted `amortized / serialized` decode-byte ratio — the
    /// headline "N variants ≠ N× bytes read" number (≈ 1/N when I/O
    /// dominates; 1.0 when the workload has no strip I/O at all).
    pub fn bytes_ratio(&self) -> f64 {
        if self.serialized.decode_bytes == 0 {
            return 1.0;
        }
        self.amortized.decode_bytes as f64 / self.serialized.decode_bytes as f64
    }

    /// Predicted wall-clock speedup of the shared sweep over running
    /// the variants one by one.
    pub fn wall_speedup(&self) -> f64 {
        if self.amortized.wall_secs <= 0.0 {
            return 1.0;
        }
        self.serialized.wall_secs / self.amortized.wall_secs
    }
}

/// The analytic model. See module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// ns/px/pass compute floors at the calibration ks, per (kernel,
    /// layout). Fused shares the pruned series (see [`prior_key`]), so
    /// the map always holds exactly the measured kernel × layout pairs.
    priors: BTreeMap<(KernelChoice, TileLayout), Vec<(usize, f64)>>,
    /// Wall ns per byte read beyond one clean image pass per fill pass.
    pub decode_ns_per_byte: f64,
    /// Largest relative prediction error vs the calibration matrix —
    /// the model's stated honesty bound (see module docs).
    pub error_bound: f64,
    /// The SIMD capability level this model prices the Simd kernel at.
    /// The planner stamps the run's resolved level here before
    /// enumerating candidates; the library default (Portable) keeps
    /// predictions architecture-independent.
    pub simd_level: SimdLevel,
    /// Per-level simd-over-lanes ratios (see [`SimdScale`]).
    pub simd_scale: SimdScale,
    /// Wall nanoseconds per byte moved over a shard transport
    /// (serialize + copy + kernel crossing, loopback/UDS-calibrated).
    /// Prices [`CostModel::predict_sharded`]'s closed-form byte count;
    /// refinable the same way as `decode_ns_per_byte`.
    pub wire_ns_per_byte: f64,
}

/// Fixed wire overhead terms, mirrored from `crate::shard::wire`'s
/// frame layout (a test cross-checks them against the real encoder).
const WIRE_FRAME_HEADER: u64 = 20;
/// `Register` frame bytes beyond the shipped pixels: header + job id +
/// the fixed [`crate::shard::spec`] encoding.
const WIRE_REGISTER_FIXED: u64 = WIRE_FRAME_HEADER + 8 + 118;
/// `Block` frame bytes beyond the centroid payload (+ drift when sent).
const WIRE_BLOCK_FIXED: u64 = WIRE_FRAME_HEADER + 34;
/// `StepResult`/`AssignResult` frame bytes beyond sums/labels.
const WIRE_RESULT_FIXED: u64 = WIRE_FRAME_HEADER + 64;
/// `Ping`/`Pong` frame bytes (header + job id).
const WIRE_PING: u64 = WIRE_FRAME_HEADER + 8;

/// Closed-form bytes a full sharded run moves over the wire, returned
/// as `(down, up)` from the leader's perspective:
///
/// - **warmup** (per connection): one `Register` carrying the spec and
///   the whole image (`4·h·w·c`), one `Ping` down; `RegisterAck` +
///   `Pong` up.
/// - **per step round** (per block): centroids down (`4·k·c` payload on
///   a fixed 54-byte frame) plus the drift vector (`8·k + 8`) on every
///   round after the first; `f64` partial sums up
///   (`84 + 8·k + 8·k·c`).
/// - **final assign round** (per block): centroids + drift down;
///   fixed-84 frames plus `4` bytes per pixel of labels up.
/// - **shutdown** (per connection): one bare frame down.
///
/// `EXPERIMENTS.md` §Distributed derives the same form;
/// `python/check_distributed_schema.py` holds `BENCH_distributed.json`
/// to it exactly.
pub fn sharded_wire_bytes(w: &Workload, blocks: usize, connections: usize) -> (u64, u64) {
    let (b, n_c) = (blocks as u64, connections as u64);
    let (k, c) = (w.k as u64, w.channels as u64);
    let step_rounds = w.rounds as u64;
    let centroids = 4 * k * c;
    let drift = 8 * k + 8;
    let block_frames = b * (step_rounds + 1);
    // Drift rides on every frame after round 1: (step_rounds - 1) step
    // rounds plus the assign round = step_rounds frames per block.
    let down = n_c * (WIRE_REGISTER_FIXED + w.image_bytes() + WIRE_PING)
        + block_frames * (WIRE_BLOCK_FIXED + centroids)
        + b * step_rounds * drift
        + n_c * WIRE_FRAME_HEADER;
    let up = n_c * (WIRE_FRAME_HEADER + WIRE_PING)
        + b * step_rounds * (WIRE_RESULT_FIXED + 8 * k + 8 * k * c)
        + b * WIRE_RESULT_FIXED
        + (w.pixels() as u64) * 4;
    (down, up)
}

/// Fused reuses the pruned floor and Simd the lanes floor (neither has
/// a committed row of its own) — scaled at lookup time, so refinement
/// of the underlying series flows through.
fn prior_key(kernel: KernelChoice, layout: TileLayout) -> (KernelChoice, TileLayout) {
    let k = match kernel {
        KernelChoice::Fused => KernelChoice::Pruned,
        KernelChoice::Simd => KernelChoice::Lanes,
        other => other,
    };
    (k, layout)
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::baked()
    }
}

impl CostModel {
    /// The committed priors: row-shaped floors and the decode fit from
    /// the repo's `BENCH_layout.json` (1024², 3 bands, strips of 64
    /// rows, 4 workers, memory backing). Regenerate with
    /// `python3 python/bench_plan_model.py --print-priors`.
    pub fn baked() -> CostModel {
        let mut priors = BTreeMap::new();
        let table: [(KernelChoice, TileLayout, [f64; 3]); 6] = [
            (KernelChoice::Naive, TileLayout::Interleaved, [60.983, 100.972, 177.864]),
            (KernelChoice::Naive, TileLayout::Soa, [61.987, 100.356, 179.150]),
            (KernelChoice::Pruned, TileLayout::Interleaved, [46.226, 94.565, 153.081]),
            (KernelChoice::Pruned, TileLayout::Soa, [46.771, 94.458, 157.109]),
            (KernelChoice::Lanes, TileLayout::Interleaved, [28.415, 54.463, 74.355]),
            (KernelChoice::Lanes, TileLayout::Soa, [27.301, 54.629, 74.319]),
        ];
        for (kernel, layout, ns) in table {
            priors.insert(
                prior_key(kernel, layout),
                CALIB_KS.iter().copied().zip(ns).collect(),
            );
        }
        CostModel {
            priors,
            decode_ns_per_byte: 0.07848,
            error_bound: 0.5611,
            simd_level: SimdLevel::default(),
            simd_scale: SimdScale::default(),
            wire_ns_per_byte: DEFAULT_WIRE_NS_PER_BYTE,
        }
    }

    /// Re-derive every prior from a `BENCH_layout.json`-shaped document
    /// (rust bench or python model output): row cells become compute
    /// floors, naive column/square cells fit the decode coefficient.
    pub fn calibrate_from_json(text: &str) -> Result<CostModel> {
        let doc = Json::parse(text).context("parse layout bench json")?;
        let cases = doc
            .get("cases")
            .and_then(Json::as_arr)
            .context("layout bench json has no cases")?;
        let img = doc.get("image").and_then(Json::as_arr).context("image")?;
        let n_px = img
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0))
            .product::<f64>();
        let passes = doc.get("iters").and_then(Json::as_f64).context("iters")? + 1.0;

        let field = |c: &Json, k: &str| -> Result<f64> {
            c.get(k).and_then(Json::as_f64).with_context(|| format!("case field {k}"))
        };
        // Parse one case's identity up front — a typo'd kernel/layout
        // label in a calibration document is a clean error here, not a
        // missing-prior panic at prediction time.
        let cell_key = |c: &Json| -> Result<(KernelChoice, TileLayout, String, usize)> {
            let s = |name: &str| -> Result<&str> {
                c.get(name)
                    .and_then(Json::as_str)
                    .with_context(|| format!("case field {name}"))
            };
            Ok((
                s("kernel")?.parse().map_err(anyhow::Error::msg)?,
                s("layout")?.parse().map_err(anyhow::Error::msg)?,
                s("shape")?.to_string(),
                field(c, "k")? as usize,
            ))
        };

        let mut priors: BTreeMap<(KernelChoice, TileLayout), Vec<(usize, f64)>> = BTreeMap::new();
        // ((kernel, layout), k) -> row-cell (ns, bytes); then fit decode
        // from naive non-row cells against their row baseline.
        let mut row_cells: BTreeMap<((KernelChoice, TileLayout), usize), (f64, f64)> =
            BTreeMap::new();
        for c in cases {
            let (kernel, layout, shape, k) = cell_key(c)?;
            if shape == "row" {
                let ns = field(c, "ns_per_pixel_round")?;
                row_cells.insert(((kernel, layout), k), (ns, field(c, "bytes_read")?));
                priors.entry((kernel, layout)).or_default().push((k, ns));
            }
        }
        for kernel in [KernelChoice::Naive, KernelChoice::Pruned, KernelChoice::Lanes] {
            for layout in [TileLayout::Interleaved, TileLayout::Soa] {
                anyhow::ensure!(
                    priors.contains_key(&prior_key(kernel, layout)),
                    "calibration document has no row cells for {kernel}/{layout}"
                );
            }
        }
        for series in priors.values_mut() {
            series.sort_unstable_by_key(|&(k, _)| k);
            series.dedup_by_key(|&mut (k, _)| k);
        }

        let (mut num, mut den) = (0.0f64, 0.0f64);
        for c in cases {
            let (kernel, layout, shape, k) = cell_key(c)?;
            if kernel != KernelChoice::Naive || shape == "row" {
                continue;
            }
            let Some(&(row_ns, row_bytes)) = row_cells.get(&((kernel, layout), k)) else {
                continue;
            };
            let excess_ns = (field(c, "ns_per_pixel_round")? - row_ns) * n_px * passes;
            let excess_bytes = field(c, "bytes_read")? - row_bytes;
            num += excess_ns * excess_bytes;
            den += excess_bytes * excess_bytes;
        }
        let decode_ns_per_byte = if den > 0.0 { (num / den).max(0.0) } else { 0.0 };

        let mut model = CostModel {
            priors,
            decode_ns_per_byte,
            error_bound: 0.0,
            simd_level: SimdLevel::default(),
            simd_scale: SimdScale::default(),
            // The layout matrix carries no wire measurements; the
            // default survives recalibration.
            wire_ns_per_byte: DEFAULT_WIRE_NS_PER_BYTE,
        };
        // Stated bound = worst self-prediction over the matrix, floored
        // at 10% so a tiny matrix cannot claim implausible precision.
        let mut worst = 0.10f64;
        for c in cases {
            let (kernel, layout, _, k) = cell_key(c)?;
            let measured = field(c, "ns_per_pixel_round")?;
            let floor = model.compute_ns_px_pass(kernel, layout, k);
            let Some(&(_, row_bytes)) = row_cells.get(&((kernel, layout), k)) else {
                continue;
            };
            let excess = (field(c, "bytes_read")? - row_bytes).max(0.0);
            let predicted = floor + excess * model.decode_ns_per_byte / (n_px * passes);
            if measured > 0.0 {
                worst = worst.max((predicted - measured).abs() / measured);
            }
        }
        model.error_bound = worst;
        Ok(model)
    }

    /// Compute floor (ns/px/pass) for a kernel/layout at cluster count
    /// `k`: piecewise-linear between calibration points, clamped at the
    /// ends, scaled for fused (see [`prior_key`]).
    pub fn compute_ns_px_pass(&self, kernel: KernelChoice, layout: TileLayout, k: usize) -> f64 {
        let series = self
            .priors
            .get(&prior_key(kernel, layout))
            .expect("every kernel/layout pair has a prior series");
        let base = interp(series, k);
        match kernel {
            KernelChoice::Fused => base * FUSED_OVER_PRUNED,
            KernelChoice::Simd => base * self.simd_scale.get(self.simd_level),
            _ => base,
        }
    }

    /// Feed the startup microbench's measured simd-over-lanes wall
    /// ratio for a level into the model. Clamped to a sane band so one
    /// noisy measurement can neither zero the Simd floor nor banish the
    /// kernel entirely; non-finite or non-positive ratios are ignored.
    pub fn calibrate_simd(&mut self, level: SimdLevel, measured_ratio: f64) {
        if measured_ratio.is_finite() && measured_ratio > 0.0 {
            self.simd_scale.set(level, measured_ratio.clamp(0.25, 4.0));
        }
    }

    /// Blend an observed ns/px/pass into the prior nearest to `k`
    /// (equal-weight EWMA). This is the `BlockCost` / `simtime`
    /// feedback path: callers reduce a real run or a replay to one
    /// observed number and feed it back.
    pub fn refine(&mut self, kernel: KernelChoice, layout: TileLayout, k: usize, observed: f64) {
        if !(observed.is_finite() && observed > 0.0) {
            return;
        }
        let series = self
            .priors
            .get_mut(&prior_key(kernel, layout))
            .expect("every kernel/layout pair has a prior series");
        let nearest = series
            .iter_mut()
            .min_by_key(|(ck, _)| ck.abs_diff(k))
            .expect("prior series is non-empty");
        let observed = match kernel {
            // Store fused observations back in pruned-floor units (and
            // simd observations in lanes units, at the current level).
            KernelChoice::Fused => observed / FUSED_OVER_PRUNED,
            KernelChoice::Simd => observed / self.simd_scale.get(self.simd_level),
            _ => observed,
        };
        nearest.1 = 0.5 * nearest.1 + 0.5 * observed;
    }

    /// Total strip transfers (decoding reads) and bytes for a plan's
    /// geometry, closed form — mirrors what `AccessStats` will count.
    ///
    /// - SoA tiles fill once per job; interleaved re-reads every pass.
    /// - A cache holding every strip collapses all re-reads to one
    ///   decode per strip for the whole run. Partial caches get no
    ///   credit (pessimistic: hit rate depends on access order).
    fn transfers(
        &self,
        w: &Workload,
        plan: &BlockPlan,
        layout: TileLayout,
        strip_cache: usize,
    ) -> (u64, u64) {
        let Some(strip_rows) = w.strip_rows else {
            return (0, 0);
        };
        let strip_rows = strip_rows.max(1);
        let (per_pass, strips, _) = crate::stripstore::read_amplification(plan, strip_rows);
        let fill_passes = match layout {
            TileLayout::Soa => 1,
            TileLayout::Interleaved => w.passes(),
        };
        let transfers = if strip_cache >= strips && strips > 0 {
            strips as u64
        } else {
            (per_pass * fill_passes) as u64
        };
        let strip_bytes = (strip_rows * w.width * w.channels * 4) as u64;
        (transfers, transfers * strip_bytes)
    }

    /// Predicted peak resident **pixel bytes** for one candidate
    /// strategy — the feasibility side of the `--mem-mb` budget. The
    /// terms mirror what the runtime actually keeps live (and what the
    /// [`crate::util::mem::ResidentGauge`] audits):
    ///
    /// - the store: the whole image when memory-backed (or under direct
    ///   I/O), ~2 transient strips when file-backed (streaming ingest);
    /// - per worker: one decoded strip plus the block crop buffer, and
    ///   a second set for the prefetch sidecar's private reader when the
    ///   candidate double-buffers;
    /// - the decoded-strip cache (file backing only — memory-backed
    ///   caches are presence markers over the shared buffer);
    /// - the SoA tile arena, capped at its own budget and at the padded
    ///   job footprint; a transient padded tile per worker for lane
    ///   kernels running over interleaved reads;
    /// - the label map: dense `h·w·4` when unbounded; under any budget
    ///   the sink spools to disk (the same rule the runtime applies, so
    ///   model and gauge cannot disagree about where labels live), and
    ///   only its one-row buffer is resident.
    #[allow(clippy::too_many_arguments)]
    pub fn resident_bytes(
        &self,
        w: &Workload,
        plan: &BlockPlan,
        kernel: KernelChoice,
        layout: TileLayout,
        workers: usize,
        strip_cache: usize,
        prefetch: bool,
        arena_mb: usize,
        file_backed: bool,
        mem_budget: Option<u64>,
    ) -> u64 {
        let workers = workers.max(1) as u64;
        let image = w.image_bytes();
        let (brows, bcols) = plan.block_dims();
        let block_bytes = (brows * bcols * w.channels * 4) as u64;
        let mut total = match w.strip_rows {
            // Direct I/O: the raster itself is resident.
            None => image + workers * block_bytes,
            Some(strip_rows) => {
                let strip_bytes = (strip_rows.max(1) * w.width * w.channels * 4) as u64;
                let store = if file_backed { 2 * strip_bytes } else { image };
                // Reader footprint: decoded strip + bounded raw-decode
                // chunk + block crop.
                let chunk = strip_bytes
                    .min(crate::stripstore::StripReader::DECODE_CHUNK_BYTES as u64);
                let mut per_worker = strip_bytes + chunk + block_bytes;
                if prefetch {
                    per_worker *= 2; // sidecar reader + banked fill
                }
                let cache = if file_backed {
                    (strip_cache.min(w.unique_strips()) as u64) * strip_bytes
                } else {
                    0
                };
                store + workers * per_worker + cache
            }
        };
        if layout == TileLayout::Soa {
            let arena = (workers * ((arena_mb as u64) << 20)).min(image * 5 / 4);
            total += arena;
        } else if matches!(kernel, KernelChoice::Lanes | KernelChoice::Simd) {
            // Transient padded tile per worker when lanes/simd read
            // interleaved blocks.
            total += workers * (block_bytes * 5 / 4);
        }
        total += match mem_budget {
            Some(_) => (w.width * 4) as u64,
            None => (w.pixels() * 4) as u64,
        };
        total
    }

    /// Predict the cost of running `w` under the given strategy.
    pub fn predict(
        &self,
        w: &Workload,
        plan: &BlockPlan,
        kernel: KernelChoice,
        layout: TileLayout,
        workers: usize,
        strip_cache: usize,
        prefetch: bool,
    ) -> PlanCost {
        let n_px = w.pixels() as f64;
        let passes = w.passes() as f64;
        let blocks = plan.len();
        let workers = workers.max(1);

        // Worker scaling relative to the reference the priors were
        // measured at: ideal 1/W with W clamped to the block count (a
        // 5-block plan cannot use a 16th worker), corrected by
        // per-round barrier imbalance ceil(B/W)·W/B on both sides.
        // Combined, the ratio reduces to exactly
        // ceil(B/min(W,B)) / ceil(B/min(REF,B)).
        let eff = |wk: usize| wk.min(blocks).max(1);
        let imbalance = |wk: usize| {
            let wk = eff(wk);
            (blocks.div_ceil(wk) * wk) as f64 / blocks as f64
        };
        let scale = (eff(REF_WORKERS) as f64 / eff(workers) as f64) * imbalance(workers)
            / imbalance(REF_WORKERS);

        let floor = self.compute_ns_px_pass(kernel, layout, w.k);
        let compute_secs = n_px * passes * floor * scale / 1e9;

        let (strip_transfers, decode_bytes) = self.transfers(w, plan, layout, strip_cache);
        // Excess beyond one clean image pass per fill pass — that much
        // is already inside the row-calibrated floor.
        let fill_passes = match layout {
            TileLayout::Soa => 1u64,
            TileLayout::Interleaved => w.passes() as u64,
        };
        let baseline_bytes = w.image_bytes() * fill_passes;
        let excess_bytes = decode_bytes.saturating_sub(baseline_bytes) as f64;
        let io_secs = excess_bytes * self.decode_ns_per_byte * scale / 1e9;

        // Prefetch overlaps the excess decode with compute instead of
        // serializing behind it.
        let wall_secs = if prefetch {
            compute_secs.max(io_secs)
        } else {
            compute_secs + io_secs
        };
        PlanCost {
            wall_secs,
            ns_per_pixel_pass: wall_secs * 1e9 / (n_px * passes),
            compute_secs,
            io_secs,
            decode_bytes,
            strip_transfers,
        }
    }

    /// Predict the cost of running `w` distributed over `shards` shard
    /// processes with `conns_per_shard` connections into each
    /// (`shards == 0` = solo: exactly [`CostModel::predict`] at
    /// `conns_per_shard` workers).
    ///
    /// Compute and excess-decode terms reuse [`CostModel::predict`] at
    /// `shards · conns_per_shard` effective lanes — shard kernels are
    /// the same code, and the lane scaling already clamps to the block
    /// count and prices barrier imbalance. On top rides the wire term:
    /// [`sharded_wire_bytes`]'s closed form priced at
    /// [`CostModel::wire_ns_per_byte`], charged *unscaled* because every
    /// byte funnels through the single leader. The per-connection
    /// `Register` cost (the whole image, per connection) is what makes
    /// small workloads lose: distribution pays only when the saved
    /// compute exceeds the freight, and `--auto` sees exactly that
    /// trade.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_sharded(
        &self,
        w: &Workload,
        plan: &BlockPlan,
        kernel: KernelChoice,
        layout: TileLayout,
        conns_per_shard: usize,
        strip_cache: usize,
        prefetch: bool,
        shards: usize,
    ) -> PlanCost {
        if shards == 0 {
            return self.predict(w, plan, kernel, layout, conns_per_shard, strip_cache, prefetch);
        }
        let lanes = shards * conns_per_shard.max(1);
        let base = self.predict(w, plan, kernel, layout, lanes, strip_cache, prefetch);
        let (down, up) = sharded_wire_bytes(w, plan.len(), lanes);
        let wire_secs = (down + up) as f64 * self.wire_ns_per_byte / 1e9;
        let wall_secs = base.wall_secs + wire_secs;
        PlanCost {
            wall_secs,
            ns_per_pixel_pass: wall_secs * 1e9 / (w.pixels() as f64 * w.passes() as f64),
            // The wire term reports as I/O: it is the same "moving
            // bytes instead of computing" axis the explain table ranks.
            io_secs: base.io_secs + wire_secs,
            ..base
        }
    }

    /// Sweep-aware cost: `ks` variants over **one** image (same
    /// geometry, varying cluster count), predicted both ways.
    ///
    /// - *Serialized* is the naive plan: every term of [`predict`]
    ///   summed over the variants — N variants read the image N times.
    /// - *Amortized* is the share-group plan the [`crate::sweep`]
    ///   runner executes: compute still sums (every variant does its
    ///   own Lloyd arithmetic — bit-identity forbids sharing that), but
    ///   the I/O terms are **one** variant's, because content-keyed
    ///   tiles and the shared strip store decode each strip once for
    ///   the whole group. The transfer count is k-independent, so one
    ///   variant's I/O stands for the group's exactly.
    ///
    /// [`predict`]: CostModel::predict
    #[allow(clippy::too_many_arguments)]
    pub fn predict_sweep(
        &self,
        w: &Workload,
        ks: &[usize],
        plan: &BlockPlan,
        kernel: KernelChoice,
        layout: TileLayout,
        workers: usize,
        strip_cache: usize,
        prefetch: bool,
    ) -> SweepCost {
        let zero = PlanCost {
            wall_secs: 0.0,
            ns_per_pixel_pass: 0.0,
            compute_secs: 0.0,
            io_secs: 0.0,
            decode_bytes: 0,
            strip_transfers: 0,
        };
        let per: Vec<PlanCost> = ks
            .iter()
            .map(|&k| {
                let wk = Workload { k, ..*w };
                self.predict(&wk, plan, kernel, layout, workers, strip_cache, prefetch)
            })
            .collect();
        let Some(first) = per.first().copied() else {
            return SweepCost { amortized: zero, serialized: zero };
        };

        let n_px = w.pixels() as f64;
        let total_passes = (w.passes() * ks.len()) as f64;
        let serialized = PlanCost {
            wall_secs: per.iter().map(|c| c.wall_secs).sum(),
            ns_per_pixel_pass: per.iter().map(|c| c.wall_secs).sum::<f64>() * 1e9
                / (n_px * total_passes),
            compute_secs: per.iter().map(|c| c.compute_secs).sum(),
            io_secs: per.iter().map(|c| c.io_secs).sum(),
            decode_bytes: per.iter().map(|c| c.decode_bytes).sum(),
            strip_transfers: per.iter().map(|c| c.strip_transfers).sum(),
        };

        let compute_secs: f64 = per.iter().map(|c| c.compute_secs).sum();
        let wall_secs = if prefetch {
            compute_secs.max(first.io_secs)
        } else {
            compute_secs + first.io_secs
        };
        let amortized = PlanCost {
            wall_secs,
            ns_per_pixel_pass: wall_secs * 1e9 / (n_px * total_passes),
            compute_secs,
            io_secs: first.io_secs,
            decode_bytes: first.decode_bytes,
            strip_transfers: first.strip_transfers,
        };
        SweepCost { amortized, serialized }
    }
}

/// Piecewise-linear interpolation over a sorted `(k, ns)` series,
/// clamped outside the calibrated range.
fn interp(series: &[(usize, f64)], k: usize) -> f64 {
    debug_assert!(!series.is_empty());
    if k <= series[0].0 {
        return series[0].1;
    }
    if let Some(&(last_k, last_ns)) = series.last() {
        if k >= last_k {
            return last_ns;
        }
    }
    for pair in series.windows(2) {
        let (k0, v0) = pair[0];
        let (k1, v1) = pair[1];
        if k <= k1 {
            let t = (k - k0) as f64 / (k1 - k0) as f64;
            return v0 + t * (v1 - v0);
        }
    }
    series.last().unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};

    fn workload(strip_rows: Option<usize>) -> Workload {
        Workload {
            height: 1024,
            width: 1024,
            channels: 3,
            k: 4,
            rounds: 4,
            strip_rows,
        }
    }

    #[test]
    fn baked_priors_cover_every_kernel_layout() {
        let m = CostModel::baked();
        for kernel in KernelChoice::ALL {
            for layout in [TileLayout::Interleaved, TileLayout::Soa] {
                for k in [1, 2, 3, 4, 6, 8, 16] {
                    let ns = m.compute_ns_px_pass(kernel, layout, k);
                    assert!(ns > 0.0 && ns.is_finite(), "{kernel} {layout} k={k}");
                }
            }
        }
    }

    #[test]
    fn interpolation_is_monotone_between_calibration_points() {
        let m = CostModel::baked();
        let at = |k| m.compute_ns_px_pass(KernelChoice::Naive, TileLayout::Interleaved, k);
        assert_eq!(at(1), at(2), "clamped below");
        assert_eq!(at(8), at(12), "clamped above");
        assert!(at(2) < at(3) && at(3) < at(4), "linear inside");
        assert!((at(3) - (60.983 + 100.972) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn fused_floor_tracks_pruned() {
        let m = CostModel::baked();
        for k in [2, 4, 8] {
            let pruned = m.compute_ns_px_pass(KernelChoice::Pruned, TileLayout::Interleaved, k);
            let fused = m.compute_ns_px_pass(KernelChoice::Fused, TileLayout::Interleaved, k);
            assert!((fused - pruned * 0.96).abs() < 1e-9);
        }
    }

    #[test]
    fn simd_floor_tracks_lanes_at_the_stamped_level() {
        let mut m = CostModel::baked();
        for (level, scale) in [
            (SimdLevel::Portable, 1.0),
            (SimdLevel::Neon, 0.82),
            (SimdLevel::Avx2, 0.72),
            (SimdLevel::Avx512, 0.58),
        ] {
            m.simd_level = level;
            for k in [2, 4, 8] {
                let lanes = m.compute_ns_px_pass(KernelChoice::Lanes, TileLayout::Soa, k);
                let simd = m.compute_ns_px_pass(KernelChoice::Simd, TileLayout::Soa, k);
                assert!((simd - lanes * scale).abs() < 1e-9, "{level:?} k={k}");
            }
        }
    }

    #[test]
    fn simd_calibration_clamps_and_rejects_junk() {
        let mut m = CostModel::baked();
        m.calibrate_simd(SimdLevel::Avx2, 0.65);
        assert!((m.simd_scale.get(SimdLevel::Avx2) - 0.65).abs() < 1e-12);
        // Outliers clamp to the sane band instead of poisoning the model.
        m.calibrate_simd(SimdLevel::Avx2, 0.01);
        assert!((m.simd_scale.get(SimdLevel::Avx2) - 0.25).abs() < 1e-12);
        m.calibrate_simd(SimdLevel::Avx2, 99.0);
        assert!((m.simd_scale.get(SimdLevel::Avx2) - 4.0).abs() < 1e-12);
        // Junk measurements are ignored outright.
        m.calibrate_simd(SimdLevel::Avx2, f64::NAN);
        m.calibrate_simd(SimdLevel::Avx2, -1.0);
        m.calibrate_simd(SimdLevel::Avx2, 0.0);
        assert!((m.simd_scale.get(SimdLevel::Avx2) - 4.0).abs() < 1e-12);
        // Other levels are untouched.
        assert!((m.simd_scale.get(SimdLevel::Portable) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn refine_stores_simd_observations_in_lanes_units() {
        let mut m = CostModel::baked();
        m.simd_level = SimdLevel::Avx2;
        let before = m.compute_ns_px_pass(KernelChoice::Lanes, TileLayout::Soa, 4);
        // Observe simd running exactly at its predicted floor: the
        // shared lanes series must not move.
        let predicted = m.compute_ns_px_pass(KernelChoice::Simd, TileLayout::Soa, 4);
        m.refine(KernelChoice::Simd, TileLayout::Soa, 4, predicted);
        let after = m.compute_ns_px_pass(KernelChoice::Lanes, TileLayout::Soa, 4);
        assert!((after - before).abs() < 1e-9);
    }

    #[test]
    fn lanes_beat_naive_under_the_model() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        let plan = BlockPlan::new(1024, 1024, BlockShape::Rows { band_rows: 205 });
        let naive = m.predict(&w, &plan, KernelChoice::Naive, TileLayout::Interleaved, 4, 0, false);
        let lanes = m.predict(&w, &plan, KernelChoice::Lanes, TileLayout::Soa, 4, 0, false);
        assert!(lanes.wall_secs < naive.wall_secs);
    }

    #[test]
    fn column_shape_pays_decode_and_cache_recovers_it() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        let cols = BlockPlan::new(1024, 1024, BlockShape::Cols { band_cols: 205 });
        let rows = BlockPlan::new(1024, 1024, BlockShape::Rows { band_rows: 205 });
        let pick = |plan, cache| {
            m.predict(&w, plan, KernelChoice::Naive, TileLayout::Interleaved, 4, cache, false)
        };
        let col_uncached = pick(&cols, 0);
        let row_uncached = pick(&rows, 0);
        assert!(col_uncached.io_secs > 0.0, "column re-reads must cost");
        assert!(col_uncached.wall_secs > row_uncached.wall_secs);
        // a cache holding all 16 strips collapses the re-reads
        let col_cached = pick(&cols, 16);
        assert!(col_cached.wall_secs < col_uncached.wall_secs);
        assert_eq!(col_cached.strip_transfers, 16);
    }

    #[test]
    fn direct_io_has_no_decode_term() {
        let m = CostModel::baked();
        let w = workload(None);
        let plan = BlockPlan::new(1024, 1024, BlockShape::Cols { band_cols: 205 });
        let c = m.predict(&w, &plan, KernelChoice::Naive, TileLayout::Interleaved, 4, 0, false);
        assert_eq!(c.io_secs, 0.0);
        assert_eq!(c.decode_bytes, 0);
    }

    #[test]
    fn prefetch_overlaps_never_worsens() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        for shape in [
            BlockShape::Cols { band_cols: 205 },
            BlockShape::Square { side: 459 },
        ] {
            let plan = BlockPlan::new(1024, 1024, shape);
            let plain =
                m.predict(&w, &plan, KernelChoice::Naive, TileLayout::Interleaved, 4, 0, false);
            let pf = m.predict(&w, &plan, KernelChoice::Naive, TileLayout::Interleaved, 4, 0, true);
            assert!(pf.wall_secs <= plain.wall_secs);
        }
    }

    #[test]
    fn more_workers_predict_less_wall() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        let plan = BlockPlan::new(1024, 1024, BlockShape::Square { side: 459 });
        let at = |wk| {
            m.predict(&w, &plan, KernelChoice::Naive, TileLayout::Interleaved, wk, 0, false)
                .wall_secs
        };
        assert!(at(8) < at(4));
        assert!(at(4) < at(1));
    }

    #[test]
    fn worker_scaling_saturates_at_the_block_count() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        // 5 row blocks: a 16th worker has nothing to do.
        let plan = BlockPlan::new(1024, 1024, BlockShape::Rows { band_rows: 205 });
        let at = |wk| {
            m.predict(&w, &plan, KernelChoice::Naive, TileLayout::Interleaved, wk, 0, false)
                .wall_secs
        };
        assert_eq!(at(16), at(5), "scaling must clamp to the block count");
        // 4 workers run 5 blocks in 2 waves; 5 workers in 1: exact ceil ratio.
        assert!((at(4) / at(5) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn resident_model_tracks_backing_and_height() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        let plan = BlockPlan::new(1024, 1024, BlockShape::Rows { band_rows: 64 });
        let res = |file_backed| {
            m.resident_bytes(
                &w,
                &plan,
                KernelChoice::Naive,
                TileLayout::Interleaved,
                4,
                0,
                false,
                0,
                file_backed,
                None,
            )
        };
        let image = w.image_bytes();
        assert!(res(false) > image, "memory backing holds the image");
        let strip_bytes = (64 * 1024 * 3 * 4) as u64;
        assert_eq!(
            res(false) - res(true),
            image - 2 * strip_bytes,
            "backings differ by exactly the store term"
        );
        // File-backed footprint is height-independent: quadruple the
        // height, same strips/blocks per worker.
        let tall = Workload {
            height: 4096,
            ..w
        };
        let tall_plan = BlockPlan::new(4096, 1024, BlockShape::Rows { band_rows: 64 });
        let tall_res = m.resident_bytes(
            &tall,
            &tall_plan,
            KernelChoice::Naive,
            TileLayout::Interleaved,
            4,
            0,
            false,
            0,
            true,
            None,
        );
        // labels stay dense without a budget and scale with the image;
        // compare the pixel-side terms by subtracting them.
        let labels = (w.pixels() * 4) as u64;
        let tall_labels = (tall.pixels() * 4) as u64;
        assert_eq!(res(true) - labels, tall_res - tall_labels);
        // under a budget the dense label map spills out of residency
        let budget = Some(8u64 << 20);
        let with_budget = m.resident_bytes(
            &tall,
            &tall_plan,
            KernelChoice::Naive,
            TileLayout::Interleaved,
            4,
            0,
            false,
            0,
            true,
            budget,
        );
        assert!(with_budget <= 8 << 20, "{with_budget}");
        // prefetch doubles the per-worker read path
        let pf = m.resident_bytes(
            &w,
            &plan,
            KernelChoice::Naive,
            TileLayout::Interleaved,
            4,
            0,
            true,
            0,
            true,
            None,
        );
        assert!(pf > res(true));
        // a file-backed cache is real bytes; memory-backed is free
        let cached = m.resident_bytes(
            &w,
            &plan,
            KernelChoice::Naive,
            TileLayout::Interleaved,
            4,
            16,
            false,
            0,
            true,
            None,
        );
        assert_eq!(cached - res(true), 16 * (64 * 1024 * 3 * 4) as u64);
    }

    #[test]
    fn refine_moves_the_floor_halfway() {
        let mut m = CostModel::baked();
        let before = m.compute_ns_px_pass(KernelChoice::Naive, TileLayout::Soa, 4);
        m.refine(KernelChoice::Naive, TileLayout::Soa, 4, before * 2.0);
        let after = m.compute_ns_px_pass(KernelChoice::Naive, TileLayout::Soa, 4);
        assert!((after - before * 1.5).abs() < 1e-9);
        // garbage observations are ignored
        m.refine(KernelChoice::Naive, TileLayout::Soa, 4, f64::NAN);
        m.refine(KernelChoice::Naive, TileLayout::Soa, 4, -1.0);
        assert_eq!(m.compute_ns_px_pass(KernelChoice::Naive, TileLayout::Soa, 4), after);
    }

    #[test]
    fn wire_constants_match_the_real_encoder() {
        use crate::shard::spec::SPEC_FIXED_BYTES;
        use crate::shard::wire::{FrameKind, ShardMsg, HEADER_LEN};
        assert_eq!(WIRE_FRAME_HEADER as usize, HEADER_LEN);
        assert_eq!(WIRE_REGISTER_FIXED as usize, HEADER_LEN + 8 + SPEC_FIXED_BYTES);
        let ping = ShardMsg::Ping { job: 1 }.to_frame(0);
        assert_eq!(WIRE_PING as usize, ping.wire_len());
        assert_eq!(ping.kind, FrameKind::Ping);
        // Block fixed bytes = an empty-payload block frame.
        let block = ShardMsg::Block {
            job: 1,
            block: 0,
            round: 1,
            phase: crate::shard::wire::BlockPhase::Step,
            k: 0,
            channels: 0,
            centroids: vec![],
            drift: None,
        }
        .to_frame(0);
        assert_eq!(WIRE_BLOCK_FIXED as usize, block.wire_len());
        let step = ShardMsg::StepResult {
            job: 1,
            block: 0,
            round: 1,
            k: 0,
            channels: 0,
            counts: vec![],
            sums: vec![],
            inertia: 0.0,
            io_secs: 0.0,
            compute_secs: 0.0,
            pixels: 0,
        }
        .to_frame(0);
        assert_eq!(WIRE_RESULT_FIXED as usize, step.wire_len());
    }

    #[test]
    fn wire_bytes_follow_the_closed_form() {
        let w = workload(None); // 1024x1024x3, k=4, 4 rounds
        let (down, up) = sharded_wire_bytes(&w, 4, 2);
        // down: 2 conns * (146 + 12MiB + 28) + 4 blocks * 5 frames *
        // (54 + 48) + 4 blocks * 4 drift rounds * 40 + 2 * 20
        let image = 1024u64 * 1024 * 3 * 4;
        assert_eq!(down, 2 * (146 + image + 28) + 4 * 5 * (54 + 48) + 4 * 4 * 40 + 2 * 20);
        // up: 2 conns * (20 + 28) + 4 blocks * 4 step rounds *
        // (84 + 32 + 96) + 4 blocks * 84 + 4MiB of labels
        assert_eq!(up, 2 * 48 + 4 * 4 * (84 + 32 + 96) + 4 * 84 + 1024 * 1024 * 4);
    }

    #[test]
    fn distribution_pays_at_scale_and_loses_when_tiny() {
        let m = CostModel::baked();
        let shape = BlockShape::Square { side: 512 };
        // Big workload, many rounds: saved compute dwarfs the freight.
        let big = Workload {
            height: 4096,
            width: 4096,
            channels: 3,
            k: 8,
            rounds: 30,
            strip_rows: None,
        };
        let plan = BlockPlan::new(4096, 4096, shape);
        let solo =
            m.predict_sharded(&big, &plan, KernelChoice::Lanes, TileLayout::Soa, 4, 0, false, 0);
        let dist =
            m.predict_sharded(&big, &plan, KernelChoice::Lanes, TileLayout::Soa, 4, 0, false, 4);
        assert!(
            dist.wall_secs < solo.wall_secs,
            "4 shards {} vs solo {}",
            dist.wall_secs,
            solo.wall_secs
        );
        // Tiny workload whose 4 blocks the solo lanes already saturate:
        // extra shards cannot save compute (the scaling clamps at the
        // block count), so every wire byte — dominated by the whole
        // image shipping per connection — is pure loss.
        let tiny = Workload {
            height: 64,
            width: 64,
            channels: 3,
            k: 2,
            rounds: 2,
            strip_rows: None,
        };
        let tiny_plan = BlockPlan::new(64, 64, BlockShape::Square { side: 32 });
        let solo = m.predict_sharded(
            &tiny, &tiny_plan, KernelChoice::Lanes, TileLayout::Soa, 4, 0, false, 0,
        );
        let dist = m.predict_sharded(
            &tiny, &tiny_plan, KernelChoice::Lanes, TileLayout::Soa, 4, 0, false, 4,
        );
        assert_eq!(dist.compute_secs, solo.compute_secs, "saturated: nothing to save");
        assert!(
            dist.wall_secs > solo.wall_secs,
            "4 shards {} vs solo {}",
            dist.wall_secs,
            solo.wall_secs
        );
        // shards == 0 is exactly the solo prediction.
        let plain = m.predict(&tiny, &tiny_plan, KernelChoice::Lanes, TileLayout::Soa, 4, 0, false);
        assert_eq!(solo, plain);
    }

    #[test]
    fn sweep_amortizes_io_but_never_compute() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        let ks = [2, 4, 8];
        let plan = BlockPlan::new(1024, 1024, BlockShape::Cols { band_cols: 205 });
        let s = m.predict_sweep(
            &w, &ks, &plan, KernelChoice::Naive, TileLayout::Interleaved, 4, 0, false,
        );
        // Transfers are k-independent, so serialized I/O is exactly N×.
        assert_eq!(s.serialized.decode_bytes, 3 * s.amortized.decode_bytes);
        assert_eq!(s.serialized.strip_transfers, 3 * s.amortized.strip_transfers);
        assert!((s.bytes_ratio() - 1.0 / 3.0).abs() < 1e-12);
        // Bit-identity forbids sharing arithmetic: compute sums both ways.
        assert!((s.amortized.compute_secs - s.serialized.compute_secs).abs() < 1e-12);
        // Column shapes re-read under this config, so sharing must win wall.
        assert!(s.amortized.io_secs > 0.0);
        assert!(s.amortized.wall_secs < s.serialized.wall_secs);
        assert!(s.wall_speedup() > 1.0);
    }

    #[test]
    fn single_variant_sweep_degenerates_to_predict() {
        let m = CostModel::baked();
        let w = workload(Some(64));
        let plan = BlockPlan::new(1024, 1024, BlockShape::Cols { band_cols: 205 });
        let one = m.predict(&w, &plan, KernelChoice::Pruned, TileLayout::Soa, 4, 0, false);
        let s = m.predict_sweep(
            &w, &[w.k], &plan, KernelChoice::Pruned, TileLayout::Soa, 4, 0, false,
        );
        assert_eq!(s.amortized, one);
        assert_eq!(s.serialized, one);
        assert_eq!(s.bytes_ratio(), 1.0);
        assert_eq!(s.wall_speedup(), 1.0);
    }

    #[test]
    fn direct_io_sweep_has_nothing_to_amortize() {
        let m = CostModel::baked();
        let w = workload(None);
        let plan = BlockPlan::new(1024, 1024, BlockShape::Square { side: 459 });
        let s = m.predict_sweep(
            &w, &[2, 4], &plan, KernelChoice::Naive, TileLayout::Interleaved, 4, 0, false,
        );
        assert_eq!(s.amortized.decode_bytes, 0);
        assert_eq!(s.bytes_ratio(), 1.0, "no strip I/O: ratio pins to 1");
        // Wall still sums — a sweep is never cheaper than its compute.
        assert!((s.amortized.wall_secs - s.serialized.wall_secs).abs() < 1e-12);
    }

    #[test]
    fn calibrates_from_committed_layout_bench() {
        // The committed file lives at the repo root, two levels up from
        // the crate manifest.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_layout.json");
        let text = std::fs::read_to_string(path).expect("committed BENCH_layout.json");
        let m = CostModel::calibrate_from_json(&text).unwrap();
        let baked = CostModel::baked();
        for kernel in [KernelChoice::Naive, KernelChoice::Pruned, KernelChoice::Lanes] {
            for layout in [TileLayout::Interleaved, TileLayout::Soa] {
                for k in CALIB_KS {
                    let a = m.compute_ns_px_pass(kernel, layout, k);
                    let b = baked.compute_ns_px_pass(kernel, layout, k);
                    assert!(
                        (a - b).abs() / b < 0.005,
                        "{kernel} {layout} k={k}: calibrated {a} vs baked {b}"
                    );
                }
            }
        }
        assert!((m.decode_ns_per_byte - baked.decode_ns_per_byte).abs() < 0.005);
        assert!(m.error_bound <= baked.error_bound);
    }
}
