//! The persistent clustering server: one warm pool, many jobs.
//!
//! [`ClusterServer::start`] spawns a worker pool **once** plus a serving
//! thread that owns it. Each submitted [`JobSpec`] becomes an active job
//! with its own [`RunMachine`] (per-job reduction state); the serving
//! loop streams tagged outcomes off the pool and routes them by job id:
//!
//! ```text
//!   submit ──▶ admission gate ──▶ serving loop
//!                                   │ activate: register ctx, round 0
//!                                   ├─ outcome(job A, block i) ─▶ A.absorb
//!                                   ├─ outcome(job B, block j) ─▶ B.absorb
//!                                   │    round complete? reduce, next round
//!                                   └─ done/failed/cancelled: retire job,
//!                                      release admission slot
//! ```
//!
//! Because every job's round is submitted as a whole and the dynamic
//! queue drains per-job deques round-robin, blocks from different
//! images interleave on the workers — a straggling job cannot
//! head-of-line-block the rest — while each job's reduction stays in
//! block order and therefore bit-identical to a solo
//! [`crate::coordinator::Coordinator`] run with the same seed.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::admission::{Admission, AdmissionSnapshot};
use super::job::{HandleShared, JobHandle, JobInput, JobSpec, JobStatus};
use crate::coordinator::{
    run_fingerprint, BlockSource, ClusterMode, ClusterOutput, Engine, IoMode, Job, JobError,
    JobId, JobOutcome, RunMachine, Schedule, WorkerContext, WorkerPool,
};
use crate::image::Raster;
use crate::kmeans::StreamInit;
use crate::resilience::{Checkpoint, FaultPlan};
use crate::shard::{spawn_shard_pool, ShardEndpoints, ShardSpec};
use crate::stripstore::{Backing, StripStore};

/// Server construction parameters.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Worker thread count for the shared pool.
    pub workers: usize,
    /// Block scheduling policy ([`Schedule::Dynamic`] interleaves jobs
    /// round-robin; [`Schedule::Static`] pins block `i` to worker
    /// `i % W` per round).
    pub schedule: Schedule,
    /// Admission cap: at most this many jobs open at once; further
    /// `submit` calls block (backpressure) and `try_submit` calls shed.
    pub max_in_flight: usize,
    /// Distribute block execution to shard processes: the shared pool
    /// becomes `workers` proxy connections per shard instead of local
    /// threads. Jobs must carry in-memory raster inputs with the native
    /// engine; share groups and fault injection stay solo-only.
    pub shards: Option<ShardEndpoints>,
    /// Watchdog heartbeat timeout in ms for the shared pool
    /// (0 = [`crate::resilience::DEFAULT_HEARTBEAT_TIMEOUT_MS`]).
    pub heartbeat_ms: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 4,
            schedule: Schedule::Dynamic,
            max_in_flight: 4,
            shards: None,
            heartbeat_ms: 0,
        }
    }
}

/// Aggregate serving counters (monotone; see [`ClusterServer::stats`]).
#[derive(Clone, Copy, Debug)]
pub struct ServerStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Jobs shed by QoS admission under overload: incoming work turned
    /// away at a full gate plus lower-priority victims preempted to
    /// make room.
    pub shed: u64,
    /// Jobs terminated by a per-job or drain deadline.
    pub deadlined: u64,
    /// High water of simultaneously open (registered) jobs on the pool —
    /// the instrumentation the admission tests assert against.
    pub max_open_jobs: usize,
    pub admission: AdmissionSnapshot,
}

#[derive(Default)]
struct StatsShared {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    shed: AtomicU64,
    deadlined: AtomicU64,
    max_open_jobs: AtomicUsize,
}

struct NewJob {
    id: JobId,
    spec: JobSpec,
    handle: Arc<HandleShared>,
}

/// Serving-thread inbox traffic: admitted jobs, plus the one-shot
/// drain order.
enum ServeMsg {
    Job(NewJob),
    Drain {
        deadline: Instant,
        report: Sender<DrainReport>,
    },
}

/// What happened to each job that was still open when
/// [`ClusterServer::drain`] was called — the operator's audit trail
/// that no admitted work was silently lost.
#[derive(Debug, Default)]
pub struct DrainReport {
    /// `(job id, disposition)` in finalization order: "done",
    /// "failed: …", "cancelled", or "checkpointed to <path>".
    pub dispositions: Vec<(JobId, String)>,
}

/// Open (admitted, not yet terminal) jobs by id: the QoS admission
/// gate consults this to pick a preemption victim, the serving loop
/// prunes it at finalization.
type OpenJobs = Arc<Mutex<HashMap<JobId, (usize, Arc<HandleShared>)>>>;

/// Process-global sequence for file-backed strip-store directories: job
/// ids restart at 1 per server, so two servers in one process (or the
/// same TMPDIR shared across processes, hence the pid) must still get
/// distinct backing paths.
static STORE_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn job_store_dir(id: JobId) -> PathBuf {
    let seq = STORE_DIR_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "blockms_service_p{}_{seq}_job{id}",
        std::process::id()
    ))
}

/// The persistent multi-job clustering service. See module docs.
pub struct ClusterServer {
    cfg: ServerConfig,
    tx: Option<Sender<ServeMsg>>,
    admission: Arc<Admission>,
    stats: Arc<StatsShared>,
    open: OpenJobs,
    next_id: AtomicU64,
    serving: Option<JoinHandle<()>>,
}

impl ClusterServer {
    /// Spawn the shared pool and serving thread. Panics when a shard
    /// endpoint refuses the connection — use [`ClusterServer::try_start`]
    /// where that is an expected runtime condition.
    pub fn start(cfg: ServerConfig) -> ClusterServer {
        Self::try_start(cfg).expect("start cluster server")
    }

    /// Fallible start: connecting to remote shard endpoints is the only
    /// step that can fail.
    pub fn try_start(cfg: ServerConfig) -> Result<ClusterServer> {
        let admission = Arc::new(Admission::new(cfg.max_in_flight));
        let stats = Arc::new(StatsShared::default());
        let open: OpenJobs = Arc::default();
        let (tx, rx) = channel();
        let serving = {
            let stats = Arc::clone(&stats);
            let admission = Arc::clone(&admission);
            let open = Arc::clone(&open);
            let (pool, guards, sharded) = match &cfg.shards {
                Some(endpoints) => {
                    let (pool, guards) = spawn_shard_pool(endpoints, cfg.workers)?;
                    (pool, guards, true)
                }
                None => (WorkerPool::spawn(cfg.workers, cfg.schedule), Vec::new(), false),
            };
            if cfg.heartbeat_ms > 0 {
                pool.set_heartbeat_timeout_ms(cfg.heartbeat_ms as u64);
            }
            std::thread::Builder::new()
                .name("blockms-serve".to_string())
                .spawn(move || {
                    ServingLoop::new(pool, admission, stats, open, sharded).run(rx);
                    // Loopback shard guards join their handler threads
                    // only after the serving loop shut the pool down
                    // (dropping the proxy transports unblocks them).
                    drop(guards);
                })
                .expect("spawn serving thread")
        };
        Ok(ClusterServer {
            cfg,
            tx: Some(tx),
            admission,
            stats,
            open,
            // Solo Coordinator runs own SOLO_JOB = 0; service ids start at 1.
            next_id: AtomicU64::new(1),
            serving: Some(serving),
        })
    }

    pub fn workers(&self) -> usize {
        self.cfg.workers
    }

    /// Submit a job, blocking while the admission gate is full
    /// (backpressure). Returns the handle once the job is accepted.
    pub fn submit(&self, spec: JobSpec) -> Result<JobHandle> {
        spec.validate().context("invalid job spec")?;
        self.admission.acquire();
        self.dispatch(spec)
    }

    /// Submit without blocking: `Ok(None)` means the gate is full and
    /// the job was shed (nothing was queued). QoS admission: when the
    /// gate is full but an open job ranks **strictly below** the
    /// incoming one ([`crate::plan::ExecPlan::priority`]), the
    /// lowest-priority open job is cancelled to make room and the
    /// incoming job is admitted instead — overload sheds cheap work
    /// first, never the other way around.
    pub fn try_submit(&self, spec: JobSpec) -> Result<Option<JobHandle>> {
        spec.validate().context("invalid job spec")?;
        if self.admission.try_acquire() {
            return self.dispatch(spec).map(Some);
        }
        // Among the lowest-priority open jobs, prefer shedding the
        // newest (largest id): the oldest has the most sunk work.
        let victim = {
            let open = self.open.lock().unwrap();
            open.iter()
                .min_by_key(|&(&id, &(prio, _))| (prio, std::cmp::Reverse(id)))
                .filter(|(_, (prio, _))| *prio < spec.exec.priority)
                .map(|(_, (_, h))| Arc::clone(h))
        };
        self.stats.shed.fetch_add(1, Ordering::Relaxed);
        match victim {
            Some(h) => {
                h.request_cancel();
                // The victim's slot frees once the serving loop drains
                // its in-flight blocks; this bounded wait is the price
                // of preemptive admission.
                self.admission.acquire();
                self.dispatch(spec).map(Some)
            }
            None => Ok(None),
        }
    }

    fn dispatch(&self, spec: JobSpec) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shared = Arc::new(HandleShared::new());
        self.open
            .lock()
            .unwrap()
            .insert(id, (spec.exec.priority, Arc::clone(&shared)));
        let new = NewJob {
            id,
            spec,
            handle: Arc::clone(&shared),
        };
        // `tx` is only dropped by shutdown/Drop, which need exclusive
        // access — so it is always present here; a failed send means the
        // serving thread itself died.
        let tx = self.tx.as_ref().expect("sender present while server is alive");
        if tx.send(ServeMsg::Job(new)).is_err() {
            self.open.lock().unwrap().remove(&id);
            self.admission.release();
            anyhow::bail!("serving loop is gone");
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(JobHandle::new(id, shared))
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            submitted: self.stats.submitted.load(Ordering::Relaxed),
            completed: self.stats.completed.load(Ordering::Relaxed),
            failed: self.stats.failed.load(Ordering::Relaxed),
            cancelled: self.stats.cancelled.load(Ordering::Relaxed),
            shed: self.stats.shed.load(Ordering::Relaxed),
            deadlined: self.stats.deadlined.load(Ordering::Relaxed),
            max_open_jobs: self.stats.max_open_jobs.load(Ordering::Relaxed),
            admission: self.admission.snapshot(),
        }
    }

    /// Graceful drain: stop admitting, give in-flight jobs `timeout`
    /// to finish, then checkpoint what can be checkpointed (global
    /// mode) and cancel the rest. Temp state is swept, the pool is
    /// joined, and every job open at the drain call gets a line in the
    /// returned report — nothing admitted is silently lost.
    pub fn drain(mut self, timeout: Duration) -> DrainReport {
        let (rtx, rrx) = channel();
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(ServeMsg::Drain {
                deadline: Instant::now() + timeout,
                report: rtx,
            });
            // tx drops here: the loop sees the inbox close and exits
            // once the drain completes.
        }
        let report = rrx.recv().unwrap_or_default();
        if let Some(h) = self.serving.take() {
            let _ = h.join();
        }
        report
    }

    /// Stop accepting jobs, finish everything in flight, join the pool.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.tx.take(); // serving loop drains and exits
        if let Some(h) = self.serving.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ClusterServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// One admitted job's serving-side state.
struct ActiveJob {
    machine: RunMachine,
    /// Outcomes (or tagged errors) still expected from the pool for the
    /// in-flight round. Purging queued blocks shrinks it.
    expected: usize,
    /// Keeps strip-store counters alive for the final snapshot.
    store: Option<Arc<StripStore>>,
    /// Backing-file directory to sweep once the store is fully dropped.
    store_dir: Option<PathBuf>,
    handle: Arc<HandleShared>,
    started: Instant,
    blocks: usize,
    cancelling: bool,
    failed: Option<String>,
    /// Share-group id this job belongs to, if any (amortized sweeps).
    share: Option<u64>,
    /// Per-block retry budget per round ([`crate::plan::ExecPlan::retries`]).
    retries: usize,
    /// Spare clones of the in-flight round's jobs, by block — the
    /// re-queue source when a block fails under a retry budget. Empty
    /// when `retries == 0` (no spare bookkeeping on the fast path).
    round_jobs: HashMap<usize, Job>,
    /// Retry attempts consumed per block this round.
    attempts: HashMap<usize, usize>,
    /// Wall-clock deadline ([`crate::plan::ExecPlan::deadline_ms`]),
    /// enforced at round boundaries.
    deadline: Option<Instant>,
    /// Spec-configured checkpoint path for the deadline/drain path.
    deadline_ckpt: Option<PathBuf>,
    /// Run fingerprint stamped into deadline/drain checkpoints so a
    /// resume with a different configuration is rejected.
    fingerprint: u64,
    /// Set when a deadline (per-job or drain) terminated this job:
    /// the checkpoint path, if one was written.
    deadlined: Option<Option<PathBuf>>,
    /// The job's injected fault plan, kept so finalize can open the
    /// hang latch — a worker still parked on this job's behalf must
    /// not outlive the job into the pool's eventual join.
    fault: Option<FaultPlan>,
}

/// One live share group: same-image sweep variants reusing a single
/// strip store and one arena content id. Created by the first member
/// to activate, refcounted by activations/finalizations, torn down —
/// shared tiles purged, backing dir swept — when the last member
/// leaves.
struct ShareGroup {
    /// The raster the group was created over. Later members must carry
    /// the **same** `Arc` (pointer identity) — sharing decoded tiles
    /// across different pixels would corrupt results, so a mismatch is
    /// an activation error, not a silent un-share.
    image: Arc<Raster>,
    /// Arena content id every member's tiles live under (the creating
    /// member's job id).
    content: JobId,
    /// Members activated and not yet finalized.
    refs: usize,
    store: Arc<StripStore>,
    /// Backing-file directory, swept when the group dies.
    store_dir: Option<PathBuf>,
    strip_rows: usize,
}

struct ServingLoop {
    pool: WorkerPool,
    active: HashMap<JobId, ActiveJob>,
    /// Live share groups by group id (amortized sweeps).
    groups: HashMap<u64, ShareGroup>,
    admission: Arc<Admission>,
    stats: Arc<StatsShared>,
    /// Mirror of the open-job set the QoS gate consults; pruned at
    /// finalization.
    open: OpenJobs,
    /// Drain order in effect: the hard deadline and the channel the
    /// disposition report goes back on.
    draining: Option<(Instant, Sender<DrainReport>)>,
    /// Per-job dispositions accumulated while draining.
    dispositions: Vec<(JobId, String)>,
    /// Strip-store directories of finished jobs, removed once the last
    /// worker drops its store handle (swept opportunistically and again
    /// after the pool joins).
    cleanup_dirs: Vec<PathBuf>,
    /// The pool is shard proxies, not local workers: jobs register a
    /// [`ShardSpec`] instead of a [`WorkerContext`].
    sharded: bool,
}

impl ServingLoop {
    fn new(
        pool: WorkerPool,
        admission: Arc<Admission>,
        stats: Arc<StatsShared>,
        open: OpenJobs,
        sharded: bool,
    ) -> ServingLoop {
        ServingLoop {
            pool,
            active: HashMap::new(),
            groups: HashMap::new(),
            admission,
            stats,
            open,
            draining: None,
            dispositions: Vec::new(),
            cleanup_dirs: Vec::new(),
            sharded,
        }
    }

    /// Best-effort removal of finished jobs' backing directories.
    /// `remove_dir` fails while a worker still holds the store (file
    /// present) and succeeds once the strip file's `Drop` ran; anything
    /// left is retried, with a final sweep after the pool joins.
    fn sweep_store_dirs(&mut self) {
        self.cleanup_dirs
            .retain(|d| std::fs::remove_dir(d).is_err() && d.exists());
    }

    fn run(mut self, rx: Receiver<ServeMsg>) {
        let mut accepting = true;
        loop {
            // Admit everything already queued (non-blocking).
            while accepting {
                match rx.try_recv() {
                    Ok(msg) => self.on_msg(msg),
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        accepting = false;
                    }
                }
            }
            self.check_cancels();
            self.sweep_store_dirs();
            if self.active.is_empty() {
                if !accepting {
                    break; // shut down: nothing in flight, no new work
                }
                if self.cleanup_dirs.is_empty() && self.draining.is_none() {
                    // Idle: block until a job arrives or the server closes.
                    match rx.recv() {
                        Ok(msg) => self.on_msg(msg),
                        Err(_) => accepting = false,
                    }
                } else {
                    // Idle but either retired jobs' store directories
                    // are still pending removal (workers drop their
                    // store handles moments after processing Retire) or
                    // a drain is waiting for the inbox to close. Poll
                    // briefly instead of parking.
                    use std::sync::mpsc::RecvTimeoutError;
                    match rx.recv_timeout(std::time::Duration::from_millis(20)) {
                        Ok(msg) => self.on_msg(msg),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => accepting = false,
                    }
                }
                continue;
            }
            // While draining, the receive is bounded by the drain
            // deadline; `Ok(None)` means time is up with work still
            // out. Watchdog stalls surface here as job errors and ride
            // the ordinary retry path.
            let until = self.draining.as_ref().map(|&(d, _)| d);
            match self.pool.recv_result_deadline(until) {
                Ok(Some(Ok(outcome))) => self.on_outcome(outcome),
                Ok(Some(Err(jerr))) => self.on_error(jerr),
                Ok(None) => self.drain_expired(),
                Err(_) => {
                    // Pool gone (all workers dead): fail whatever is
                    // left, forwarding the recorded root cause (the last
                    // escaped worker panic) instead of a generic notice.
                    let msg = match self.pool.hangup_cause() {
                        Some(cause) => format!("worker pool hung up: {cause}"),
                        None => "worker pool hung up".to_string(),
                    };
                    let ids: Vec<JobId> = self.active.keys().copied().collect();
                    for id in ids {
                        if let Some(aj) = self.active.get_mut(&id) {
                            aj.failed = Some(msg.clone());
                        }
                        self.finalize(id);
                    }
                    break;
                }
            }
        }
        // Deliver the drain report (if a drain was in progress) before
        // tearing the pool down — the drain caller is blocked on it.
        if let Some((_, report)) = self.draining.take() {
            let _ = report.send(DrainReport {
                dispositions: std::mem::take(&mut self.dispositions),
            });
        }
        // Join the workers, then sweep the remaining store directories —
        // every strip file's `Drop` has run once the pool is down.
        let ServingLoop {
            pool,
            mut cleanup_dirs,
            ..
        } = self;
        pool.shutdown();
        cleanup_dirs.retain(|d| std::fs::remove_dir(d).is_err() && d.exists());
    }

    fn on_msg(&mut self, msg: ServeMsg) {
        match msg {
            ServeMsg::Job(new) => self.activate(new),
            ServeMsg::Drain { deadline, report } => {
                self.draining = Some((deadline, report));
            }
        }
    }

    /// The drain deadline landed with jobs still open: checkpoint what
    /// can be checkpointed (global mode — the last round boundary, in
    /// the standard resumable format), cancel the rest, finalize
    /// everything. Late results from still-running blocks are dropped
    /// by the finalized-job guard.
    fn drain_expired(&mut self) {
        let ids: Vec<JobId> = self.active.keys().copied().collect();
        for id in ids {
            let purged = self.pool.purge_job(id);
            let aj = self.active.get_mut(&id).expect("listed as active");
            aj.expected = aj.expected.saturating_sub(purged);
            if aj.failed.is_none() && !aj.cancelling && aj.deadlined.is_none() {
                let saved = Self::save_boundary(aj, id);
                aj.deadlined = Some(saved);
            }
            self.finalize(id);
        }
    }

    /// Best-effort checkpoint of `aj`'s last completed round boundary.
    /// Returns the path on success — the spec-configured one, else a
    /// drain temp path. `None` when the machine cannot snapshot (local
    /// mode) or the write failed.
    fn save_boundary(aj: &ActiveJob, id: JobId) -> Option<PathBuf> {
        let ck = aj.machine.boundary_snapshot(aj.fingerprint)?;
        let path = aj.deadline_ckpt.clone().unwrap_or_else(|| {
            std::env::temp_dir().join(format!(
                "blockms_drain_p{}_job{id}.ckpt",
                std::process::id()
            ))
        });
        ck.save(&path).ok().map(|_| path)
    }

    /// Register the job on the pool and launch its first round.
    fn activate(&mut self, new: NewJob) {
        // Counters and the admission slot settle BEFORE the terminal
        // status publishes: a client woken by wait() may read stats()
        // immediately and must see consistent numbers.
        if new.handle.cancel_requested() {
            // Cancelled before activation: never touched the pool.
            self.open.lock().unwrap().remove(&new.id);
            self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
            self.admission.release();
            new.handle.set_status(JobStatus::Cancelled);
            if self.draining.is_some() {
                self.dispositions.push((new.id, "cancelled".to_string()));
            }
            return;
        }
        match self.try_activate(&new) {
            Ok(()) => {}
            Err(e) => {
                self.open.lock().unwrap().remove(&new.id);
                self.stats.failed.fetch_add(1, Ordering::Relaxed);
                self.admission.release();
                new.handle.set_status(JobStatus::Failed(format!("{e:#}")));
            }
        }
    }

    fn try_activate(&mut self, new: &NewJob) -> Result<()> {
        let spec = &new.spec;
        let (_, _, channels) = spec.dims();
        if self.sharded {
            // What cannot cross the shard boundary fails loudly at
            // activation, never silently degrades to local compute.
            anyhow::ensure!(
                spec.raster().is_some(),
                "sharded serving requires an in-memory raster input \
                 (streaming admission decodes leader-side only)"
            );
            anyhow::ensure!(
                matches!(spec.engine, Engine::Native),
                "sharded serving supports the native engine only"
            );
            anyhow::ensure!(
                spec.share.is_none(),
                "share groups are per-process tile state; unavailable with shards"
            );
            anyhow::ensure!(
                spec.fault.is_none(),
                "fault injection targets in-process workers; unavailable with shards"
            );
        }
        // The tiling derives from the spec's ExecPlan exactly as the
        // solo coordinator derives it — same shape, same image, same
        // plan, hence bit-identical reduction order.
        let plan = Arc::new(spec.block_plan());
        // Per-job strip store: a globally unique directory (pid + a
        // process-wide sequence + job id) so two same-shaped concurrent
        // jobs — even on different servers — never collide on a backing
        // file.
        let mut store_dir = None;
        // Arena content id: share-group joiners adopt the creator's so
        // decoded tiles are shared; everyone else keys by their own id
        // (the seed behaviour).
        let mut content = new.id;
        let (source, store, init_centroids) = if self.sharded {
            // The leader never reads pixels after the spec ships: no
            // store, no strips — just the same init draw the shards'
            // geometry fingerprints against.
            let img = spec.raster().expect("ensured above");
            let init = spec.cluster.init.centroids(
                img.as_pixels(),
                spec.cluster.k,
                channels,
                spec.cluster.seed,
            );
            (BlockSource::Direct(Arc::clone(img)), None, init)
        } else {
            match (&spec.input, &spec.io) {
                (JobInput::Raster(img), IoMode::Direct) => {
                    // Same init draw as the solo Coordinator and the
                    // sequential baseline — the root of per-job determinism.
                    let init = spec.cluster.init.centroids(
                        img.as_pixels(),
                        spec.cluster.k,
                        channels,
                        spec.cluster.seed,
                    );
                    (BlockSource::Direct(Arc::clone(img)), None, init)
                }
                (JobInput::Raster(img), IoMode::Strips { strip_rows, file_backed }) => {
                    // Same init draw whether or not the job shares a store:
                    // sharing changes *where bytes come from*, never the
                    // model — bit-identity to the solo run starts here.
                    let init = spec.cluster.init.centroids(
                        img.as_pixels(),
                        spec.cluster.k,
                        channels,
                        spec.cluster.seed,
                    );
                    let store = match spec.share.and_then(|g| self.groups.get(&g)) {
                        Some(sg) => {
                            // Join the live group: one store, one content id
                            // for N variants. Geometry must match exactly —
                            // shared tiles over different pixels would
                            // corrupt results, so mismatches fail loudly.
                            anyhow::ensure!(
                                Arc::ptr_eq(&sg.image, img),
                                "share-group member was submitted with a different image \
                                 than the group's creator (same Arc<Raster> required)"
                            );
                            anyhow::ensure!(
                                sg.strip_rows == *strip_rows,
                                "share-group strip_rows mismatch: group uses {}, job wants {}",
                                sg.strip_rows,
                                strip_rows
                            );
                            content = sg.content;
                            Arc::clone(&sg.store)
                        }
                        None => {
                            let backing = if *file_backed {
                                let dir = job_store_dir(new.id);
                                store_dir = Some(dir.clone());
                                Backing::File(dir)
                            } else {
                                Backing::Memory
                            };
                            let mut store = StripStore::new(img, *strip_rows, backing)?;
                            store.enable_cache(spec.exec.strip_cache);
                            Arc::new(store)
                        }
                    };
                    (BlockSource::Strips(Arc::clone(&store)), Some(store), init)
                }
                (input, IoMode::Strips { strip_rows, file_backed }) => {
                    // Streaming admission (path / synthetic): the pixels are
                    // decoded here, strip by strip, straight into the job's
                    // store; the init sampler rides the same single pass and
                    // draws bit-identically to the in-memory init.
                    let backing = if *file_backed || spec.exec.file_backed {
                        let dir = job_store_dir(new.id);
                        store_dir = Some(dir.clone());
                        Backing::File(dir)
                    } else {
                        Backing::Memory
                    };
                    let mut sampler = StreamInit::new(
                        &spec.cluster.init,
                        spec.cluster.k,
                        channels,
                        Some(spec.pixels()),
                        spec.cluster.seed,
                    )?;
                    let mut src = input.open_source()?;
                    let mut store =
                        StripStore::ingest(src.as_mut(), *strip_rows, backing, |_, strip| {
                            sampler.feed(strip)
                        })?;
                    store.enable_cache(spec.exec.strip_cache);
                    let store = Arc::new(store);
                    let init = sampler.finish()?;
                    (BlockSource::Strips(Arc::clone(&store)), Some(store), init)
                }
                (_, IoMode::Direct) => {
                    anyhow::bail!("streaming inputs require strip I/O (validate() enforces this)")
                }
            }
        };
        let ctx = Arc::new(WorkerContext {
            plan: Arc::clone(&plan),
            source,
            backend: spec.engine.backend_spec(spec.cluster.k, channels)?,
            fault: spec.fault.clone(),
            local_mode: spec.mode == ClusterMode::Local,
            exec: spec.exec,
            content,
        });
        // Budgeted jobs spool their label map during the run — the same
        // rule the planner's resident model assumed at admission. The
        // terminal `JobStatus::Done(ClusterOutput)` still densifies at
        // delivery (the client asked for the labels); the budget governs
        // the run, not the handoff.
        let label_budget = spec.exec.mem_budget_bytes().map(|_| 0);
        let mut machine = RunMachine::new(
            spec.mode,
            Arc::clone(&plan),
            channels,
            &spec.cluster,
            init_centroids,
            label_budget,
        );
        // One fingerprint per job config: resume validation on the way
        // in, deadline/drain checkpoints on the way out.
        let fp = {
            let (h, w, _) = spec.dims();
            run_fingerprint(h, w, channels, &spec.cluster, spec.mode)
        };
        // Service-side resume: rewind the freshly built machine to the
        // checkpointed round boundary before the first round launches.
        // The resumed job is bit-identical to an uninterrupted one (the
        // same contract the solo coordinator's `--resume` keeps).
        if let Some(path) = &spec.resume {
            let ck = Checkpoint::load(path)?;
            anyhow::ensure!(
                ck.fingerprint == fp,
                "checkpoint {} was taken by a different run configuration \
                 (fingerprint {:#018x}, this job {:#018x})",
                path.display(),
                ck.fingerprint,
                fp
            );
            machine.restore(&ck)?;
        }
        // Share-group bookkeeping only after every fallible activation
        // step: a failed join/create must not leak a refcount. The
        // group also inherits the creator's backing dir — it outlives
        // any single member.
        if let Some(g) = spec.share {
            match self.groups.get_mut(&g) {
                Some(sg) => sg.refs += 1,
                None => {
                    let strip_rows = match &spec.io {
                        IoMode::Strips { strip_rows, .. } => *strip_rows,
                        IoMode::Direct => unreachable!("validate(): share implies strips"),
                    };
                    self.groups.insert(
                        g,
                        ShareGroup {
                            image: Arc::clone(
                                spec.raster().expect("validate(): share implies raster"),
                            ),
                            content,
                            refs: 1,
                            store: Arc::clone(
                                store.as_ref().expect("share implies a strip store"),
                            ),
                            store_dir: store_dir.take(),
                            strip_rows,
                        },
                    );
                }
            }
            // Rotation affinity: co-schedule the group's members so a
            // freshly decoded tile is immediately reused by siblings.
            self.pool.set_job_group(new.id, g);
        }
        // QoS: higher-priority jobs drain first from the shared
        // rotation (no-op at the default priority 0).
        self.pool.set_job_priority(new.id, spec.exec.priority);
        if self.sharded {
            // Shard-workers rebuild the whole execution context from the
            // spec; the leader-side ctx only feeds in-process workers.
            let img = spec.raster().expect("ensured above");
            self.pool.register_shard_spec(
                new.id,
                Arc::new(ShardSpec::from_run(
                    img,
                    &spec.cluster,
                    spec.mode,
                    &spec.io,
                    &spec.exec,
                )),
            );
        } else {
            self.pool.register_job(new.id, ctx);
        }
        self.mirror_pool_stats();
        let jobs = machine.start_round(new.id);
        let expected = jobs.len();
        let retries = spec.exec.retries;
        let round_jobs = if retries > 0 {
            jobs.iter().map(|j| (j.block, j.clone())).collect()
        } else {
            HashMap::new()
        };
        self.pool.submit(jobs);
        new.handle.set_status(JobStatus::Running);
        self.active.insert(
            new.id,
            ActiveJob {
                machine,
                expected,
                store,
                store_dir,
                handle: Arc::clone(&new.handle),
                started: Instant::now(),
                blocks: plan.len(),
                cancelling: false,
                failed: None,
                share: spec.share,
                retries,
                round_jobs,
                attempts: HashMap::new(),
                deadline: (spec.exec.deadline_ms > 0).then(|| {
                    Instant::now() + Duration::from_millis(spec.exec.deadline_ms as u64)
                }),
                deadline_ckpt: spec.deadline_checkpoint.clone(),
                fingerprint: fp,
                deadlined: None,
                fault: spec.fault.clone(),
            },
        );
        Ok(())
    }

    /// Notice cancellation requests and stop feeding those jobs.
    fn check_cancels(&mut self) {
        let flagged: Vec<JobId> = self
            .active
            .iter()
            .filter(|(_, aj)| {
                !aj.cancelling && aj.failed.is_none() && aj.handle.cancel_requested()
            })
            .map(|(&id, _)| id)
            .collect();
        for id in flagged {
            self.cancel_job(id);
        }
    }

    /// Stop feeding a cancelled job: shed its queued blocks, finalize
    /// once the in-flight ones drain.
    fn cancel_job(&mut self, id: JobId) {
        let purged = self.pool.purge_job(id);
        let Some(aj) = self.active.get_mut(&id) else {
            return;
        };
        aj.cancelling = true;
        aj.expected = aj.expected.saturating_sub(purged);
        if aj.expected == 0 {
            self.finalize(id);
        }
    }

    fn on_outcome(&mut self, outcome: JobOutcome) {
        let id = outcome.job;
        let Some(aj) = self.active.get_mut(&id) else {
            return; // late straggler of an already-finalized job
        };
        if aj.cancelling || aj.failed.is_some() {
            aj.expected = aj.expected.saturating_sub(1);
            if aj.expected == 0 {
                self.finalize(id);
            }
            return;
        }
        // A hung worker escalated by the watchdog may deliver its copy
        // of a block after the re-queued spare already did (or after
        // the round moved on). Both copies computed the same pure
        // function of the round's centroids, so dropping the loser is
        // bit-exact; it owes no `expected` message (only first arrivals
        // are counted).
        if !aj.machine.wants(&outcome) {
            return;
        }
        aj.expected = aj.expected.saturating_sub(1);
        // Cancellation may land between outcomes of one round.
        if aj.handle.cancel_requested() {
            self.cancel_job(id);
            return;
        }
        match aj.machine.absorb(outcome) {
            Ok(round_done) => {
                if round_done {
                    self.advance(id);
                }
            }
            Err(e) => self.fail_job(id, format!("{e:#}")),
        }
    }

    fn on_error(&mut self, jerr: JobError) {
        let id = jerr.job;
        let Some(aj) = self.active.get_mut(&id) else {
            return;
        };
        // A late error for a block the round already has (the spare
        // raced ahead of a faulty copy): superseded, drop it.
        if aj.failed.is_none() && !aj.cancelling && !aj.machine.block_pending(jerr.block) {
            return;
        }
        // Retry path: re-queue the round's spare clone of the failed
        // block. `expected` is untouched — the fresh attempt owes one
        // more message. The failing worker already evicted its stale
        // state for this (job, block), so the recomputation is a pure
        // function of the round's centroids: bit-identical, and the
        // job's neighbours on the shared pool never notice.
        if aj.failed.is_none() && !aj.cancelling && aj.retries > 0 {
            let used = aj.attempts.entry(jerr.block).or_insert(0);
            if *used < aj.retries {
                *used += 1;
                let job = aj
                    .round_jobs
                    .get(&jerr.block)
                    .cloned()
                    .expect("round spares kept while retries are enabled");
                self.pool.submit(vec![job]);
                return;
            }
        }
        aj.expected = aj.expected.saturating_sub(1);
        if aj.failed.is_none() && !aj.cancelling {
            let msg = match aj.attempts.get(&jerr.block) {
                Some(&used) if used > 0 => format!(
                    "{jerr} (block {} failed {} attempts, retry budget {})",
                    jerr.block,
                    used + 1,
                    aj.retries
                ),
                _ => jerr.to_string(),
            };
            self.fail_job(id, msg);
        } else if aj.expected == 0 {
            self.finalize(id);
        }
    }

    /// Mark a job failed, shed its queued blocks, finalize when drained.
    fn fail_job(&mut self, id: JobId, msg: String) {
        let purged = self.pool.purge_job(id);
        let Some(aj) = self.active.get_mut(&id) else {
            return;
        };
        aj.failed = Some(msg);
        aj.expected = aj.expected.saturating_sub(purged);
        if aj.expected == 0 {
            self.finalize(id);
        }
    }

    /// A round completed cleanly: reduce it and either finish the job or
    /// launch its next round.
    fn advance(&mut self, id: JobId) {
        let finished = {
            let aj = self.active.get_mut(&id).expect("advance on active job");
            if let Err(e) = aj.machine.finish_round() {
                let msg = format!("{e:#}");
                self.fail_job(id, msg);
                return;
            }
            aj.machine.done()
        };
        if finished {
            self.finalize(id);
            return;
        }
        let aj = self.active.get_mut(&id).expect("still active");
        // Deadline enforcement happens exactly here — the round
        // boundary — where the snapshot is cheap, exact, and resumable.
        // The checkpoint (best effort; global mode) captures every
        // completed round, so a deadline costs at most one round of
        // recomputation on resume.
        if aj.deadline.is_some_and(|d| Instant::now() >= d) {
            let saved = Self::save_boundary(aj, id);
            aj.deadlined = Some(saved);
            self.finalize(id);
            return;
        }
        let jobs = aj.machine.start_round(id);
        aj.expected = jobs.len();
        if aj.retries > 0 {
            aj.round_jobs = jobs.iter().map(|j| (j.block, j.clone())).collect();
            aj.attempts.clear();
        }
        self.pool.submit(jobs);
    }

    /// Terminal transition: retire from the pool, publish the status,
    /// release the admission slot.
    fn finalize(&mut self, id: JobId) {
        let aj = self.active.remove(&id).expect("finalize on active job");
        // Wake any worker still parked by this job's hang fault: the
        // job is terminal, and a parked worker would stall its peers'
        // blocks (and the pool's shutdown join) for the rest of the
        // park. The latch is shared across clones, so this reaches the
        // copy inside the worker context.
        if let Some(f) = &aj.fault {
            f.release();
        }
        match aj.share {
            None => self.pool.retire_job(id),
            Some(g) => {
                // Refcounted teardown: only the group's last survivor
                // purges the shared tiles and sweeps the backing dir —
                // earlier leavers keep them hot for their siblings.
                let sg = self
                    .groups
                    .get_mut(&g)
                    .expect("share group alive while members are");
                sg.refs -= 1;
                if sg.refs == 0 {
                    let sg = self.groups.remove(&g).expect("just seen");
                    self.pool.retire_job_with(id, Some(sg.content));
                    if let Some(dir) = sg.store_dir {
                        self.cleanup_dirs.push(dir);
                    }
                } else {
                    self.pool.retire_job_with(id, None);
                }
            }
        }
        self.mirror_pool_stats();
        if let Some(dir) = aj.store_dir {
            self.cleanup_dirs.push(dir);
        }
        let status = if let Some(msg) = aj.failed {
            self.stats.failed.fetch_add(1, Ordering::Relaxed);
            JobStatus::Failed(msg)
        } else if let Some(checkpoint) = aj.deadlined {
            self.stats.deadlined.fetch_add(1, Ordering::Relaxed);
            JobStatus::Deadline { checkpoint }
        } else if aj.cancelling {
            self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
            JobStatus::Cancelled
        } else {
            let elapsed = aj.started.elapsed().as_secs_f64();
            let snapshot = aj.store.map(|s| s.stats().snapshot());
            match aj.machine.into_output().and_then(|m| {
                ClusterOutput::from_machine(
                    m,
                    elapsed,
                    0.0, // pool was already warm: no spawn cost
                    snapshot,
                    aj.blocks,
                    self.pool.workers(),
                )
            }) {
                Ok(out) => {
                    self.stats.completed.fetch_add(1, Ordering::Relaxed);
                    JobStatus::Done(Box::new(out))
                }
                Err(e) => {
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    JobStatus::Failed(format!("{e:#}"))
                }
            }
        };
        if self.draining.is_some() {
            let disp = match &status {
                JobStatus::Done(_) => "done".to_string(),
                JobStatus::Failed(msg) => format!("failed: {msg}"),
                JobStatus::Deadline { checkpoint: Some(p) } => {
                    format!("checkpointed to {} (resumable)", p.display())
                }
                JobStatus::Deadline { checkpoint: None } => {
                    "deadline hit; no checkpoint (local mode or write failed)".to_string()
                }
                s => s.label().to_string(),
            };
            self.dispositions.push((id, disp));
        }
        self.open.lock().unwrap().remove(&id);
        // Release the slot before publishing: a client woken by wait()
        // may read stats() immediately and must see the slot returned.
        self.admission.release();
        aj.handle.set_status(status);
        self.sweep_store_dirs();
    }

    fn mirror_pool_stats(&self) {
        self.stats
            .max_open_jobs
            .fetch_max(self.pool.max_open_jobs(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::coordinator::ClusterConfig;
    use crate::image::SyntheticOrtho;

    fn spec(seed: u64) -> JobSpec {
        let img = Arc::new(SyntheticOrtho::default().with_seed(seed).generate(32, 28));
        JobSpec::new(
            img,
            crate::plan::ExecPlan::pinned(BlockShape::Square { side: 10 }),
            ClusterConfig {
                k: 2,
                seed,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_job_completes() {
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        let out = server.submit(spec(5)).unwrap().wait_output().unwrap();
        assert_eq!(out.labels.len(), 32 * 28);
        assert!(out.iterations >= 1);
        let stats = server.stats();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.admission.in_flight, 0);
        server.shutdown();
    }

    #[test]
    fn invalid_spec_rejected_without_admission_leak() {
        let server = ClusterServer::start(ServerConfig::default());
        let mut bad = spec(1);
        bad.cluster.k = 32 * 28 + 1; // more clusters than pixels
        assert!(server.submit(bad).is_err());
        assert_eq!(server.stats().admission.in_flight, 0);
        server.shutdown();
    }

    #[test]
    fn streamed_synthetic_job_is_bit_identical_to_raster_job() {
        let gen = SyntheticOrtho::default().with_seed(41);
        let exec = crate::plan::ExecPlan::pinned(BlockShape::Square { side: 10 });
        let ccfg = ClusterConfig {
            k: 2,
            seed: 41,
            ..Default::default()
        };
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        // Raster job over strips (the seed path)…
        let img = Arc::new(gen.generate(32, 28));
        let raster_spec = JobSpec::new(Arc::clone(&img), exec, ccfg.clone()).with_io(
            IoMode::Strips {
                strip_rows: 8,
                file_backed: false,
            },
        );
        // …and the same scene admitted as a generator description,
        // decoded strip-by-strip at activation.
        let mut stream_spec = JobSpec::from_synthetic(gen, 32, 28, exec, ccfg);
        stream_spec.io = IoMode::Strips {
            strip_rows: 8,
            file_backed: true,
        };
        let a = server.submit(raster_spec).unwrap().wait_output().unwrap();
        let b = server.submit(stream_spec).unwrap().wait_output().unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.iterations, b.iterations);
        assert!((a.inertia - b.inertia).abs() == 0.0);
        server.shutdown();
    }

    #[test]
    fn streamed_ppm_job_matches_its_raster_twin() {
        let gen = SyntheticOrtho::default().with_seed(42);
        let img = gen.generate(30, 22);
        let dir = std::env::temp_dir().join("blockms_server_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("job.ppm");
        crate::image::write_ppm(&img, &path).unwrap();
        // PPM quantizes to u8 — the raster twin is the re-read file,
        // not the original f32 scene.
        let twin = Arc::new(crate::image::read_ppm(&path).unwrap());
        let exec = crate::plan::ExecPlan::pinned(BlockShape::Square { side: 9 });
        let ccfg = ClusterConfig {
            k: 2,
            seed: 7,
            ..Default::default()
        };
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        let raster_spec = JobSpec::new(twin, exec, ccfg.clone()).with_io(IoMode::Strips {
            strip_rows: 64,
            file_backed: false,
        });
        let ppm_spec = JobSpec::from_ppm(&path, exec, ccfg).unwrap();
        let a = server.submit(raster_spec).unwrap().wait_output().unwrap();
        let b = server.submit(ppm_spec).unwrap().wait_output().unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids, b.centroids);
        server.shutdown();
    }

    #[test]
    fn faulted_job_retries_in_isolation_and_matches_clean_twin() {
        use crate::resilience::{FaultKind, FaultPlan};
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        // A clean twin of the same spec establishes the expected bits.
        let clean = server.submit(spec(9)).unwrap();
        // The faulted job: block 1 fails its first visit, then heals;
        // one retry absorbs it. A clean neighbour runs concurrently.
        let fault = FaultPlan::new(1, FaultKind::Error, 1);
        let mut faulted = spec(9);
        faulted.exec = faulted.exec.with_retries(1);
        let faulted = faulted.with_fault(fault.clone());
        let neighbour = server.submit(spec(11)).unwrap();
        let faulted = server.submit(faulted).unwrap();
        let clean_out = clean.wait_output().unwrap();
        let faulted_out = faulted.wait_output().unwrap();
        let neighbour_out = neighbour.wait_output().unwrap();
        assert!(fault.trips() >= 1, "fault never fired");
        assert_eq!(faulted_out.labels, clean_out.labels);
        assert_eq!(faulted_out.centroids, clean_out.centroids);
        assert_eq!(faulted_out.inertia_trace, clean_out.inertia_trace);
        // the neighbour matches ITS clean twin (ran before the server
        // saw any fault) — isolation both ways
        let solo_neighbour = server.submit(spec(11)).unwrap().wait_output().unwrap();
        assert_eq!(neighbour_out.labels, solo_neighbour.labels);
        let stats = server.stats();
        assert_eq!(stats.failed, 0);
        server.shutdown();
    }

    #[test]
    fn zero_retry_job_fails_loudly_with_attempt_context() {
        use crate::resilience::{FaultKind, FaultPlan};
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        let bad = spec(13).with_fault(FaultPlan::always(2, FaultKind::Error));
        let status = server.submit(bad).unwrap().wait();
        let JobStatus::Failed(msg) = status else {
            panic!("expected failure, got {}", status.label());
        };
        assert!(msg.contains("injected failure"), "{msg}");
        // exhausted budgets name the attempt count
        let mut worn = spec(13).with_fault(FaultPlan::always(2, FaultKind::Error));
        worn.exec = worn.exec.with_retries(2);
        let status = server.submit(worn).unwrap().wait();
        let JobStatus::Failed(msg) = status else {
            panic!("expected failure");
        };
        assert!(
            msg.contains("3 attempts") && msg.contains("retry budget 2"),
            "{msg}"
        );
        // the server is still serviceable afterwards
        assert!(server.submit(spec(5)).unwrap().wait_output().is_ok());
        server.shutdown();
    }

    #[test]
    fn injected_panic_is_survived_and_reported_with_its_message() {
        use crate::resilience::{FaultKind, FaultPlan};
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        // Without retries the panic's own message must reach the client.
        let bad = spec(17).with_fault(FaultPlan::always(0, FaultKind::Panic));
        let JobStatus::Failed(msg) = server.submit(bad).unwrap().wait() else {
            panic!("expected failure");
        };
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("injected panic"), "{msg}");
        // With a retry budget the same panic is absorbed bit-identically.
        let clean = server.submit(spec(17)).unwrap().wait_output().unwrap();
        let mut healed = spec(17).with_fault(FaultPlan::new(0, FaultKind::Panic, 1));
        healed.exec = healed.exec.with_retries(1);
        let out = server.submit(healed).unwrap().wait_output().unwrap();
        assert_eq!(out.labels, clean.labels);
        assert_eq!(out.centroids, clean.centroids);
        server.shutdown();
    }

    #[test]
    fn retired_job_store_dir_is_swept_while_the_server_lives() {
        use crate::resilience::{FaultKind, FaultPlan};
        let gen = SyntheticOrtho::default().with_seed(23);
        let exec = crate::plan::ExecPlan::pinned(BlockShape::Square { side: 10 });
        let ccfg = ClusterConfig {
            k: 2,
            seed: 23,
            ..Default::default()
        };
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        // A failed file-backed streaming job: its per-job store dir must
        // disappear during serve, not at shutdown (the disk-leak fix).
        let service_dirs = || -> std::collections::HashSet<PathBuf> {
            let prefix = format!("blockms_service_p{}_", std::process::id());
            std::fs::read_dir(std::env::temp_dir())
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with(&prefix))
                .map(|e| e.path())
                .collect()
        };
        let before = service_dirs();
        let mut failing = JobSpec::from_synthetic(gen, 32, 28, exec, ccfg)
            .with_fault(FaultPlan::always(0, FaultKind::Error));
        failing.io = IoMode::Strips {
            strip_rows: 8,
            file_backed: true,
        };
        assert!(server.submit(failing).unwrap().wait_output().is_err());
        // Only dirs born in this test's window count — concurrent tests'
        // stores come and go independently.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let fresh: Vec<PathBuf> = service_dirs()
                .into_iter()
                .filter(|d| !before.contains(d))
                .collect();
            if fresh.is_empty() {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "store dirs leaked while the server was alive: {fresh:?}"
            );
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        server.shutdown();
    }

    #[test]
    fn share_group_jobs_match_their_solo_twins() {
        // Three k-variants over one image in a share group (one store,
        // shared tiles, co-scheduled) must be bit-identical to solo
        // submissions of the same specs.
        let img = Arc::new(SyntheticOrtho::default().with_seed(31).generate(32, 28));
        let exec = crate::plan::ExecPlan::pinned(BlockShape::Square { side: 10 });
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        let mk = |k: usize, share: Option<u64>| {
            let s = JobSpec::new(
                Arc::clone(&img),
                exec,
                ClusterConfig {
                    k,
                    seed: 31,
                    ..Default::default()
                },
            )
            .with_io(IoMode::Strips {
                strip_rows: 8,
                file_backed: false,
            });
            match share {
                Some(g) => s.with_share_group(g),
                None => s,
            }
        };
        let shared: Vec<_> = (2..5)
            .map(|k| server.submit(mk(k, Some(1))).unwrap())
            .collect();
        let shared_out: Vec<_> = shared.iter().map(|h| h.wait_output().unwrap()).collect();
        for (i, k) in (2..5).enumerate() {
            let solo = server.submit(mk(k, None)).unwrap().wait_output().unwrap();
            assert_eq!(shared_out[i].labels, solo.labels, "labels diverged at k={k}");
            assert_eq!(shared_out[i].centroids, solo.centroids, "k={k}");
            assert_eq!(shared_out[i].inertia.to_bits(), solo.inertia.to_bits(), "k={k}");
        }
        assert_eq!(server.stats().failed, 0);
        server.shutdown();
    }

    #[test]
    fn deadlined_job_checkpoints_and_resumes_bit_identically() {
        use crate::resilience::{FaultKind, FaultPlan};
        // The doomed twin runs the same fixed-6-iteration spec as the
        // clean one, but a 30ms hang on block 0 guarantees round 1
        // outlives the 1ms deadline: the job must deadline at the first
        // boundary with a checkpoint, and resuming from it (clean spec)
        // must land on the clean twin's exact bits.
        let dir = std::env::temp_dir().join("blockms_deadline_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join(format!("p{}_deadline.ckpt", std::process::id()));
        let _ = std::fs::remove_file(&ckpt);
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        let fixed = |seed| {
            let mut s = spec(seed);
            s.cluster.fixed_iters = Some(6);
            s
        };
        let clean = server.submit(fixed(19)).unwrap().wait_output().unwrap();
        let doomed = fixed(19)
            .with_fault(FaultPlan::new(0, FaultKind::Hang { ms: 30 }, 1))
            .with_deadline_ms(1)
            .with_deadline_checkpoint(ckpt.clone());
        let status = server.submit(doomed).unwrap().wait();
        let JobStatus::Deadline { checkpoint: Some(p) } = status else {
            panic!("expected a checkpointed deadline, got {}", status.label());
        };
        assert_eq!(p, ckpt);
        assert!(ckpt.exists(), "checkpoint file missing");
        let resumed = fixed(19).with_resume(ckpt.clone());
        let out = server.submit(resumed).unwrap().wait_output().unwrap();
        assert_eq!(out.labels, clean.labels);
        assert_eq!(out.centroids, clean.centroids);
        assert_eq!(out.inertia.to_bits(), clean.inertia.to_bits());
        assert_eq!(server.stats().deadlined, 1);
        server.shutdown();
        let _ = std::fs::remove_file(&ckpt);
    }

    #[test]
    fn overload_sheds_lowest_priority_first() {
        // Gate of 1, occupied by a default-priority job that cannot
        // finish on its own (a huge fixed iteration count): an equal-
        // priority try_submit is shed outright, while a priority-5
        // submission preempts the squatter and takes its slot.
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            max_in_flight: 1,
            ..Default::default()
        });
        let mut squatter = spec(3);
        squatter.cluster.fixed_iters = Some(1_000_000);
        let low = server.submit(squatter).unwrap();
        assert!(
            server.try_submit(spec(7)).unwrap().is_none(),
            "equal priority must shed the incoming job"
        );
        let high = server
            .try_submit(spec(5).with_priority(5))
            .unwrap()
            .expect("high-priority job must preempt, not shed");
        assert!(high.wait_output().is_ok());
        // The squatter can only end one way: preempted and cancelled.
        assert!(matches!(low.wait(), JobStatus::Cancelled));
        let stats = server.stats();
        assert_eq!(
            stats.shed, 2,
            "the turned-away job and the preempted victim both count: {stats:?}"
        );
        server.shutdown();
    }

    #[test]
    fn drain_reports_every_open_job() {
        // A job already finished before the drain isn't "open" and owes
        // no disposition; a job that cannot finish inside the drain
        // window must be checkpointed and reported — admitted work is
        // never silently lost.
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            ..Default::default()
        });
        let fast = server.submit(spec(5)).unwrap();
        fast.wait(); // done before the drain begins
        let mut slow = spec(21);
        slow.cluster.fixed_iters = Some(1_000_000); // cannot finish in 200ms
        let slow = server.submit(slow).unwrap();
        let report = server.drain(Duration::from_millis(200));
        let disp: HashMap<JobId, &String> =
            report.dispositions.iter().map(|(id, d)| (*id, d)).collect();
        let slow_disp = disp.get(&slow.id()).expect("slow job must be reported");
        assert!(
            slow_disp.contains("checkpointed to"),
            "expected a checkpoint disposition, got: {slow_disp}"
        );
        let JobStatus::Deadline { checkpoint: Some(p) } = slow.wait() else {
            panic!("slow job should have deadlined with a checkpoint");
        };
        assert!(p.exists());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn server_shuts_down_cleanly_and_restarts() {
        let server = ClusterServer::start(ServerConfig::default());
        let h = server.submit(spec(2)).unwrap();
        h.wait();
        server.shutdown(); // joins the pool and serving loop
        let server2 = ClusterServer::start(ServerConfig::default());
        assert!(server2.submit(spec(3)).unwrap().wait_output().is_ok());
        server2.shutdown();
    }
}
