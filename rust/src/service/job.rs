//! Job descriptions and client-side handles.
//!
//! A [`JobSpec`] is everything one clustering request needs — image,
//! clustering parameters, a resolved [`ExecPlan`], and the
//! run-environment choices (mode, I/O model, engine). The spec embeds
//! the *same* `ExecPlan` type every other entry point consumes — it
//! does not re-declare kernel/layout/cache knobs, so the solo and
//! service paths cannot drift (a regression test in
//! `tests/plan_resolution.rs` holds them identical). Two jobs sharing a
//! pool can differ in all of these: the pool's workers key their state
//! by job id, so a k=8 fused strip-I/O job interleaves safely with a
//! k=2 naive direct-I/O one.
//!
//! Submitting a spec yields a [`JobHandle`]: a cheap, cloneable,
//! thread-safe view of the job's lifecycle
//! (`Queued → Running → Done | Failed | Cancelled`) with blocking
//! [`JobHandle::wait`] and cooperative [`JobHandle::cancel`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, ensure, Result};

use crate::blocks::BlockPlan;
use crate::coordinator::{
    ClusterConfig, ClusterMode, ClusterOutput, Engine, IoMode, JobId,
};
use crate::image::Raster;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::tile::TileLayout;
use crate::plan::ExecPlan;

/// One clustering request, self-contained: the service needs nothing
/// else to run it. Defaults mirror [`crate::coordinator::CoordinatorConfig`].
#[derive(Clone)]
pub struct JobSpec {
    pub image: Arc<Raster>,
    pub cluster: ClusterConfig,
    /// The job's resolved execution plan. The block tiling is derived
    /// from `exec.shape` at activation ([`JobSpec::block_plan`]);
    /// `exec.workers` sizes nothing here — the shared pool's width is
    /// the server's ([`crate::service::ServerConfig::workers`]).
    pub exec: ExecPlan,
    pub mode: ClusterMode,
    pub io: IoMode,
    pub engine: Engine,
    /// Fault injection for tests: this block index fails.
    pub fail_block: Option<usize>,
}

impl JobSpec {
    /// A global-mode, direct-I/O, native-engine job running `exec`.
    pub fn new(image: Arc<Raster>, exec: ExecPlan, cluster: ClusterConfig) -> JobSpec {
        JobSpec {
            image,
            cluster,
            exec,
            mode: ClusterMode::Global,
            io: IoMode::Direct,
            engine: Engine::Native,
            fail_block: None,
        }
    }

    pub fn with_mode(mut self, mode: ClusterMode) -> JobSpec {
        self.mode = mode;
        self
    }

    pub fn with_io(mut self, io: IoMode) -> JobSpec {
        self.io = io;
        self
    }

    pub fn with_engine(mut self, engine: Engine) -> JobSpec {
        self.engine = engine;
        self
    }

    /// Replace the whole execution plan.
    pub fn with_exec(mut self, exec: ExecPlan) -> JobSpec {
        self.exec = exec;
        self
    }

    /// Pin one kernel. The layout follows to the kernel's native shape
    /// (see [`ExecPlan::with_kernel`]), so call [`JobSpec::with_layout`]
    /// *after* this to keep an explicit layout choice.
    pub fn with_kernel(mut self, kernel: KernelChoice) -> JobSpec {
        self.exec = self.exec.with_kernel(kernel);
        self
    }

    /// Pin the tile layout. Order matters: [`JobSpec::with_kernel`]
    /// resets the layout to the kernel's native shape, so pin the
    /// kernel first and the layout second.
    pub fn with_layout(mut self, layout: TileLayout) -> JobSpec {
        self.exec = self.exec.with_layout(layout);
        self
    }

    pub fn with_arena_mb(mut self, arena_mb: usize) -> JobSpec {
        self.exec = self.exec.with_arena_mb(arena_mb);
        self
    }

    pub fn with_prefetch(mut self, prefetch: bool) -> JobSpec {
        self.exec = self.exec.with_prefetch(prefetch);
        self
    }

    pub fn with_strip_cache(mut self, strips: usize) -> JobSpec {
        self.exec = self.exec.with_strip_cache(strips);
        self
    }

    /// The block tiling this job runs — derived from the embedded plan
    /// against the actual image, exactly as the solo coordinator does,
    /// so identical specs tile identically on both paths.
    pub fn block_plan(&self) -> BlockPlan {
        self.exec.block_plan(self.image.height(), self.image.width())
    }

    /// Reject malformed specs at submission time, before they occupy an
    /// admission slot's worth of pool work.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.cluster.k >= 1, "k must be at least 1");
        ensure!(
            self.image.pixels() >= self.cluster.k,
            "cannot init {} clusters from {} pixels",
            self.cluster.k,
            self.image.pixels()
        );
        if let IoMode::Strips { strip_rows, .. } = self.io {
            ensure!(strip_rows > 0, "strip_rows must be positive");
        }
        Ok(())
    }
}

/// Where a job is in its lifecycle.
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Accepted (admission slot held), not yet picked up by the serving
    /// loop.
    Queued,
    /// Rounds in flight on the shared pool.
    Running,
    /// Finished; the output is bit-identical to a solo
    /// [`crate::coordinator::Coordinator::cluster`] run of the same spec.
    Done(Box<ClusterOutput>),
    /// A worker error failed this job (other jobs unaffected).
    Failed(String),
    /// Cancelled before completion; partial work was discarded.
    Cancelled,
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobStatus::Done(_) | JobStatus::Failed(_) | JobStatus::Cancelled
        )
    }

    /// Short lifecycle label (stable across payload contents).
    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done(_) => "done",
            JobStatus::Failed(_) => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }
}

/// State shared between a [`JobHandle`] and the serving loop.
pub(crate) struct HandleShared {
    status: Mutex<JobStatus>,
    cond: Condvar,
    cancel: AtomicBool,
}

impl Default for HandleShared {
    fn default() -> HandleShared {
        HandleShared::new()
    }
}

impl HandleShared {
    pub(crate) fn new() -> HandleShared {
        HandleShared {
            status: Mutex::new(JobStatus::Queued),
            cond: Condvar::new(),
            cancel: AtomicBool::new(false),
        }
    }

    /// Serving-loop side: publish a status change.
    pub(crate) fn set_status(&self, status: JobStatus) {
        let mut st = self.status.lock().unwrap();
        *st = status;
        drop(st);
        self.cond.notify_all();
    }

    /// Serving-loop side: has the client asked to cancel?
    pub(crate) fn cancel_requested(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// Client-side view of one submitted job.
#[derive(Clone)]
pub struct JobHandle {
    id: JobId,
    pub(crate) shared: Arc<HandleShared>,
}

impl JobHandle {
    pub(crate) fn new(id: JobId, shared: Arc<HandleShared>) -> JobHandle {
        JobHandle { id, shared }
    }

    /// The service-assigned job id (also tags the pool's messages).
    pub fn id(&self) -> JobId {
        self.id
    }

    /// Current status (non-blocking snapshot).
    pub fn status(&self) -> JobStatus {
        self.shared.status.lock().unwrap().clone()
    }

    /// Request cooperative cancellation. The serving loop stops issuing
    /// rounds for this job at the next outcome it routes; blocks already
    /// on workers finish and are discarded. Other jobs are untouched.
    /// Idempotent; a no-op once the job is terminal.
    pub fn cancel(&self) {
        self.shared.cancel.store(true, Ordering::Relaxed);
    }

    /// Block until the job reaches a terminal state; returns it.
    pub fn wait(&self) -> JobStatus {
        let mut st = self.shared.status.lock().unwrap();
        while !st.is_terminal() {
            st = self.shared.cond.wait(st).unwrap();
        }
        st.clone()
    }

    /// Block until terminal; `Ok` only for a completed job.
    pub fn wait_output(&self) -> Result<ClusterOutput> {
        match self.wait() {
            JobStatus::Done(out) => Ok(*out),
            JobStatus::Failed(msg) => bail!("job {} failed: {msg}", self.id),
            JobStatus::Cancelled => bail!("job {} was cancelled", self.id),
            JobStatus::Queued | JobStatus::Running => unreachable!("wait returns terminal states"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::image::SyntheticOrtho;

    fn spec(h: usize, w: usize) -> JobSpec {
        let img = Arc::new(SyntheticOrtho::default().with_seed(3).generate(h, w));
        JobSpec::new(
            img,
            ExecPlan::pinned(BlockShape::Square { side: 8 }),
            ClusterConfig::default(),
        )
    }

    #[test]
    fn valid_spec_passes() {
        assert!(spec(16, 16).validate().is_ok());
    }

    #[test]
    fn block_plan_follows_the_image() {
        // The old plan/image mismatch hazard is unrepresentable: the
        // tiling is derived from the exec plan against the image.
        let s = spec(16, 16);
        let plan = s.block_plan();
        assert_eq!((plan.height(), plan.width()), (16, 16));
        assert_eq!(plan.len(), 4);
    }

    #[test]
    fn zero_strip_rows_rejected() {
        let s = spec(16, 16).with_io(IoMode::Strips {
            strip_rows: 0,
            file_backed: false,
        });
        assert!(s.validate().is_err());
    }

    #[test]
    fn handle_status_transitions_and_wait() {
        let shared = Arc::new(HandleShared::new());
        let h = JobHandle::new(7, Arc::clone(&shared));
        assert_eq!(h.status().label(), "queued");
        assert!(!shared.cancel_requested());
        h.cancel();
        assert!(shared.cancel_requested());
        let waiter = {
            let h = h.clone();
            std::thread::spawn(move || h.wait().label())
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        shared.set_status(JobStatus::Cancelled);
        assert_eq!(waiter.join().unwrap(), "cancelled");
        assert!(h.wait_output().is_err());
    }
}
