//! Job descriptions and client-side handles.
//!
//! A [`JobSpec`] is everything one clustering request needs — image,
//! block plan, clustering parameters, and per-job execution knobs
//! (mode, I/O model, compute kernel, engine). Two jobs sharing a pool
//! can differ in *all* of these: the pool's workers key their state by
//! job id, so a k=8 fused strip-I/O job interleaves safely with a k=2
//! naive direct-I/O one.
//!
//! Submitting a spec yields a [`JobHandle`]: a cheap, cloneable,
//! thread-safe view of the job's lifecycle
//! (`Queued → Running → Done | Failed | Cancelled`) with blocking
//! [`JobHandle::wait`] and cooperative [`JobHandle::cancel`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, ensure, Result};

use crate::blocks::BlockPlan;
use crate::coordinator::{
    ClusterConfig, ClusterMode, ClusterOutput, Engine, IoMode, JobId,
};
use crate::image::Raster;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::tile::TileLayout;

/// One clustering request, self-contained: the service needs nothing
/// else to run it. Defaults mirror [`crate::coordinator::CoordinatorConfig`].
#[derive(Clone)]
pub struct JobSpec {
    pub image: Arc<Raster>,
    pub plan: Arc<BlockPlan>,
    pub cluster: ClusterConfig,
    pub mode: ClusterMode,
    pub io: IoMode,
    pub kernel: KernelChoice,
    pub engine: Engine,
    /// Block layout across rounds (`None` = the kernel's native shape;
    /// see [`crate::coordinator::CoordinatorConfig::layout`]).
    pub layout: Option<TileLayout>,
    /// Per-worker tile-arena budget in MiB (SoA layout).
    pub arena_mb: usize,
    /// Overlap next-block reads with compute on the workers.
    pub prefetch: bool,
    /// Shared decoded-strip LRU capacity in strips (0 = off).
    pub strip_cache: usize,
    /// Fault injection for tests: this block index fails.
    pub fail_block: Option<usize>,
}

impl JobSpec {
    /// A global-mode, direct-I/O, naive-kernel, native-engine job.
    pub fn new(image: Arc<Raster>, plan: Arc<BlockPlan>, cluster: ClusterConfig) -> JobSpec {
        JobSpec {
            image,
            plan,
            cluster,
            mode: ClusterMode::Global,
            io: IoMode::Direct,
            kernel: KernelChoice::Naive,
            engine: Engine::Native,
            layout: None,
            arena_mb: 256,
            prefetch: false,
            strip_cache: 0,
            fail_block: None,
        }
    }

    pub fn with_mode(mut self, mode: ClusterMode) -> JobSpec {
        self.mode = mode;
        self
    }

    pub fn with_io(mut self, io: IoMode) -> JobSpec {
        self.io = io;
        self
    }

    pub fn with_kernel(mut self, kernel: KernelChoice) -> JobSpec {
        self.kernel = kernel;
        self
    }

    pub fn with_engine(mut self, engine: Engine) -> JobSpec {
        self.engine = engine;
        self
    }

    pub fn with_layout(mut self, layout: TileLayout) -> JobSpec {
        self.layout = Some(layout);
        self
    }

    pub fn with_arena_mb(mut self, arena_mb: usize) -> JobSpec {
        self.arena_mb = arena_mb;
        self
    }

    pub fn with_prefetch(mut self, prefetch: bool) -> JobSpec {
        self.prefetch = prefetch;
        self
    }

    pub fn with_strip_cache(mut self, strips: usize) -> JobSpec {
        self.strip_cache = strips;
        self
    }

    /// The concrete layout this job runs (explicit, or the kernel's
    /// native shape).
    pub fn resolved_layout(&self) -> TileLayout {
        self.layout.unwrap_or_else(|| self.kernel.default_layout())
    }

    /// Reject malformed specs at submission time, before they occupy an
    /// admission slot's worth of pool work.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.cluster.k >= 1, "k must be at least 1");
        ensure!(
            self.plan.height() == self.image.height() && self.plan.width() == self.image.width(),
            "plan {}x{} does not match image {}x{}",
            self.plan.height(),
            self.plan.width(),
            self.image.height(),
            self.image.width()
        );
        ensure!(!self.plan.is_empty(), "block plan has no blocks");
        ensure!(
            self.image.pixels() >= self.cluster.k,
            "cannot init {} clusters from {} pixels",
            self.cluster.k,
            self.image.pixels()
        );
        if let IoMode::Strips { strip_rows, .. } = self.io {
            ensure!(strip_rows > 0, "strip_rows must be positive");
        }
        Ok(())
    }
}

/// Where a job is in its lifecycle.
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Accepted (admission slot held), not yet picked up by the serving
    /// loop.
    Queued,
    /// Rounds in flight on the shared pool.
    Running,
    /// Finished; the output is bit-identical to a solo
    /// [`crate::coordinator::Coordinator::cluster`] run of the same spec.
    Done(Box<ClusterOutput>),
    /// A worker error failed this job (other jobs unaffected).
    Failed(String),
    /// Cancelled before completion; partial work was discarded.
    Cancelled,
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobStatus::Done(_) | JobStatus::Failed(_) | JobStatus::Cancelled
        )
    }

    /// Short lifecycle label (stable across payload contents).
    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done(_) => "done",
            JobStatus::Failed(_) => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }
}

/// State shared between a [`JobHandle`] and the serving loop.
pub(crate) struct HandleShared {
    status: Mutex<JobStatus>,
    cond: Condvar,
    cancel: AtomicBool,
}

impl Default for HandleShared {
    fn default() -> HandleShared {
        HandleShared::new()
    }
}

impl HandleShared {
    pub(crate) fn new() -> HandleShared {
        HandleShared {
            status: Mutex::new(JobStatus::Queued),
            cond: Condvar::new(),
            cancel: AtomicBool::new(false),
        }
    }

    /// Serving-loop side: publish a status change.
    pub(crate) fn set_status(&self, status: JobStatus) {
        let mut st = self.status.lock().unwrap();
        *st = status;
        drop(st);
        self.cond.notify_all();
    }

    /// Serving-loop side: has the client asked to cancel?
    pub(crate) fn cancel_requested(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// Client-side view of one submitted job.
#[derive(Clone)]
pub struct JobHandle {
    id: JobId,
    pub(crate) shared: Arc<HandleShared>,
}

impl JobHandle {
    pub(crate) fn new(id: JobId, shared: Arc<HandleShared>) -> JobHandle {
        JobHandle { id, shared }
    }

    /// The service-assigned job id (also tags the pool's messages).
    pub fn id(&self) -> JobId {
        self.id
    }

    /// Current status (non-blocking snapshot).
    pub fn status(&self) -> JobStatus {
        self.shared.status.lock().unwrap().clone()
    }

    /// Request cooperative cancellation. The serving loop stops issuing
    /// rounds for this job at the next outcome it routes; blocks already
    /// on workers finish and are discarded. Other jobs are untouched.
    /// Idempotent; a no-op once the job is terminal.
    pub fn cancel(&self) {
        self.shared.cancel.store(true, Ordering::Relaxed);
    }

    /// Block until the job reaches a terminal state; returns it.
    pub fn wait(&self) -> JobStatus {
        let mut st = self.shared.status.lock().unwrap();
        while !st.is_terminal() {
            st = self.shared.cond.wait(st).unwrap();
        }
        st.clone()
    }

    /// Block until terminal; `Ok` only for a completed job.
    pub fn wait_output(&self) -> Result<ClusterOutput> {
        match self.wait() {
            JobStatus::Done(out) => Ok(*out),
            JobStatus::Failed(msg) => bail!("job {} failed: {msg}", self.id),
            JobStatus::Cancelled => bail!("job {} was cancelled", self.id),
            JobStatus::Queued | JobStatus::Running => unreachable!("wait returns terminal states"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::image::SyntheticOrtho;

    fn spec(h: usize, w: usize) -> JobSpec {
        let img = Arc::new(SyntheticOrtho::default().with_seed(3).generate(h, w));
        let plan = Arc::new(BlockPlan::new(h, w, BlockShape::Square { side: 8 }));
        JobSpec::new(img, plan, ClusterConfig::default())
    }

    #[test]
    fn valid_spec_passes() {
        assert!(spec(16, 16).validate().is_ok());
    }

    #[test]
    fn mismatched_plan_rejected() {
        let mut s = spec(16, 16);
        s.plan = Arc::new(BlockPlan::new(8, 8, BlockShape::Square { side: 4 }));
        assert!(s.validate().is_err());
    }

    #[test]
    fn zero_strip_rows_rejected() {
        let s = spec(16, 16).with_io(IoMode::Strips {
            strip_rows: 0,
            file_backed: false,
        });
        assert!(s.validate().is_err());
    }

    #[test]
    fn handle_status_transitions_and_wait() {
        let shared = Arc::new(HandleShared::new());
        let h = JobHandle::new(7, Arc::clone(&shared));
        assert_eq!(h.status().label(), "queued");
        assert!(!shared.cancel_requested());
        h.cancel();
        assert!(shared.cancel_requested());
        let waiter = {
            let h = h.clone();
            std::thread::spawn(move || h.wait().label())
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        shared.set_status(JobStatus::Cancelled);
        assert_eq!(waiter.join().unwrap(), "cancelled");
        assert!(h.wait_output().is_err());
    }
}
