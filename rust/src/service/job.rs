//! Job descriptions and client-side handles.
//!
//! A [`JobSpec`] is everything one clustering request needs — image,
//! clustering parameters, a resolved [`ExecPlan`], and the
//! run-environment choices (mode, I/O model, engine). The spec embeds
//! the *same* `ExecPlan` type every other entry point consumes — it
//! does not re-declare kernel/layout/cache knobs, so the solo and
//! service paths cannot drift (a regression test in
//! `tests/plan_resolution.rs` holds them identical). Two jobs sharing a
//! pool can differ in all of these: the pool's workers key their state
//! by job id, so a k=8 fused strip-I/O job interleaves safely with a
//! k=2 naive direct-I/O one.
//!
//! Submitting a spec yields a [`JobHandle`]: a cheap, cloneable,
//! thread-safe view of the job's lifecycle
//! (`Queued → Running → Done | Failed | Cancelled`) with blocking
//! [`JobHandle::wait`] and cooperative [`JobHandle::cancel`].

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::blocks::BlockPlan;
use crate::coordinator::{
    ClusterConfig, ClusterMode, ClusterOutput, Engine, IoMode, JobId,
};
use crate::image::{
    ppm_dims, PpmSource, Raster, RasterCursor, RasterSource, SyntheticOrtho, SyntheticSource,
};
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::tile::TileLayout;
use crate::kmeans::InitMethod;
use crate::plan::ExecPlan;
use crate::resilience::FaultPlan;

/// Where a job's pixels come from. Admission never requires the pixels
/// — a path or a generator description is enough; streaming inputs are
/// decoded strip-by-strip at activation (the out-of-core path).
#[derive(Clone)]
pub enum JobInput {
    /// A pre-loaded raster (the seed behaviour; direct or strip I/O).
    Raster(Arc<Raster>),
    /// A binary PPM on disk. Only the header is read at submission;
    /// activation streams the payload into the job's strip store.
    PpmFile(PathBuf),
    /// A synthetic scene generated strip-by-strip at activation.
    Synthetic {
        gen: SyntheticOrtho,
        height: usize,
        width: usize,
    },
}

impl JobInput {
    /// Does running this input require streaming ingestion (no resident
    /// raster to crop from)?
    pub fn is_streaming(&self) -> bool {
        !matches!(self, JobInput::Raster(_))
    }

    /// Open a sequential decoder over this input. For `Raster` the
    /// cursor serves the resident buffer (back-compat through the same
    /// ingest path).
    pub fn open_source(&self) -> Result<Box<dyn RasterSource>> {
        Ok(match self {
            JobInput::Raster(img) => Box::new(RasterCursor::new(Arc::clone(img))),
            JobInput::PpmFile(path) => Box::new(PpmSource::open(path)?),
            JobInput::Synthetic { gen, height, width } => {
                Box::new(SyntheticSource::new(gen, *height, *width))
            }
        })
    }
}

/// One clustering request, self-contained: the service needs nothing
/// else to run it. Defaults mirror [`crate::coordinator::CoordinatorConfig`].
#[derive(Clone)]
pub struct JobSpec {
    pub input: JobInput,
    /// Geometry `(height, width, channels)`, known at submission for
    /// every input kind (header read for files) so admission and
    /// validation never touch pixels.
    dims: (usize, usize, usize),
    pub cluster: ClusterConfig,
    /// The job's resolved execution plan. The block tiling is derived
    /// from `exec.shape` at activation ([`JobSpec::block_plan`]);
    /// `exec.workers` sizes nothing here — the shared pool's width is
    /// the server's ([`crate::service::ServerConfig::workers`]).
    pub exec: ExecPlan,
    pub mode: ClusterMode,
    pub io: IoMode,
    pub engine: Engine,
    /// Deterministic fault injection (tests, fault drills): which block
    /// fails, how, and on which visits. Retry budget rides on
    /// [`ExecPlan::retries`].
    pub fault: Option<FaultPlan>,
    /// Resume this job from a checkpoint file written by an earlier run
    /// of the same configuration (global mode). Loaded at activation;
    /// a fingerprint or format mismatch fails the job at that point.
    pub resume: Option<PathBuf>,
    /// Share-group tag for amortized sweeps. Jobs submitted with the
    /// same group id over the **same image** share one strip store and
    /// one set of decoded arena tiles (content-keyed), and are
    /// co-scheduled so shared strips stay hot — N variants cost ~1
    /// read. `None` (the default) keeps the seed's fully isolated
    /// per-job I/O. Activation validates that group members agree on
    /// geometry and strip layout; results stay bit-identical to solo
    /// runs either way.
    pub share: Option<u64>,
    /// Where to save the last round boundary if this job's deadline
    /// ([`ExecPlan::deadline_ms`]) or the server's drain deadline lands
    /// before it finishes. `None` lets the server pick a temp path at
    /// drain time; a deadlined job always reports its checkpoint (if
    /// any) in [`JobStatus::Deadline`]. Global mode only.
    pub deadline_checkpoint: Option<PathBuf>,
}

impl JobSpec {
    /// A global-mode, direct-I/O, native-engine job running `exec`.
    pub fn new(image: Arc<Raster>, exec: ExecPlan, cluster: ClusterConfig) -> JobSpec {
        let dims = (image.height(), image.width(), image.channels());
        JobSpec {
            input: JobInput::Raster(image),
            dims,
            cluster,
            exec,
            mode: ClusterMode::Global,
            io: IoMode::Direct,
            engine: Engine::Native,
            fault: None,
            resume: None,
            share: None,
            deadline_checkpoint: None,
        }
    }

    /// A job over a PPM file, admitted by path: only the header is read
    /// here. Defaults to strip I/O (streaming needs it), file-backed
    /// when the plan says so.
    pub fn from_ppm(path: &Path, exec: ExecPlan, cluster: ClusterConfig) -> Result<JobSpec> {
        let dims = ppm_dims(path).with_context(|| format!("admit {}", path.display()))?;
        Ok(JobSpec {
            input: JobInput::PpmFile(path.to_path_buf()),
            dims,
            cluster,
            exec,
            mode: ClusterMode::Global,
            io: IoMode::Strips {
                strip_rows: 64,
                file_backed: exec.file_backed,
            },
            engine: Engine::Native,
            fault: None,
            resume: None,
            share: None,
            deadline_checkpoint: None,
        })
    }

    /// A job over a synthetic scene generated at activation.
    pub fn from_synthetic(
        gen: SyntheticOrtho,
        height: usize,
        width: usize,
        exec: ExecPlan,
        cluster: ClusterConfig,
    ) -> JobSpec {
        let dims = (height, width, gen.channels);
        JobSpec {
            input: JobInput::Synthetic { gen, height, width },
            dims,
            cluster,
            exec,
            mode: ClusterMode::Global,
            io: IoMode::Strips {
                strip_rows: 64,
                file_backed: exec.file_backed,
            },
            engine: Engine::Native,
            fault: None,
            resume: None,
            share: None,
            deadline_checkpoint: None,
        }
    }

    /// The resident raster, when this job was submitted with one
    /// (streaming jobs have none until activation decodes them).
    pub fn raster(&self) -> Option<&Arc<Raster>> {
        match &self.input {
            JobInput::Raster(img) => Some(img),
            _ => None,
        }
    }

    /// Geometry `(height, width, channels)` without touching pixels.
    pub fn dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    /// Total pixel count.
    pub fn pixels(&self) -> usize {
        self.dims.0 * self.dims.1
    }

    pub fn with_mode(mut self, mode: ClusterMode) -> JobSpec {
        self.mode = mode;
        self
    }

    pub fn with_io(mut self, io: IoMode) -> JobSpec {
        self.io = io;
        self
    }

    pub fn with_engine(mut self, engine: Engine) -> JobSpec {
        self.engine = engine;
        self
    }

    /// Replace the whole execution plan.
    pub fn with_exec(mut self, exec: ExecPlan) -> JobSpec {
        self.exec = exec;
        self
    }

    /// Pin one kernel. The layout follows to the kernel's native shape
    /// (see [`ExecPlan::with_kernel`]), so call [`JobSpec::with_layout`]
    /// *after* this to keep an explicit layout choice.
    pub fn with_kernel(mut self, kernel: KernelChoice) -> JobSpec {
        self.exec = self.exec.with_kernel(kernel);
        self
    }

    /// Pin the tile layout. Order matters: [`JobSpec::with_kernel`]
    /// resets the layout to the kernel's native shape, so pin the
    /// kernel first and the layout second.
    pub fn with_layout(mut self, layout: TileLayout) -> JobSpec {
        self.exec = self.exec.with_layout(layout);
        self
    }

    pub fn with_arena_mb(mut self, arena_mb: usize) -> JobSpec {
        self.exec = self.exec.with_arena_mb(arena_mb);
        self
    }

    pub fn with_prefetch(mut self, prefetch: bool) -> JobSpec {
        self.exec = self.exec.with_prefetch(prefetch);
        self
    }

    pub fn with_strip_cache(mut self, strips: usize) -> JobSpec {
        self.exec = self.exec.with_strip_cache(strips);
        self
    }

    /// Inject a deterministic fault into this job's blocks.
    pub fn with_fault(mut self, fault: FaultPlan) -> JobSpec {
        self.fault = Some(fault);
        self
    }

    /// Resume from a checkpoint written by an earlier run of the same
    /// configuration.
    pub fn with_resume(mut self, path: PathBuf) -> JobSpec {
        self.resume = Some(path);
        self
    }

    /// Join share group `group`: same-image jobs under one group id
    /// share a strip store and decoded tiles (see [`JobSpec::share`]).
    pub fn with_share_group(mut self, group: u64) -> JobSpec {
        self.share = Some(group);
        self
    }

    /// Give the job a wall-clock deadline: the serving loop cancels it
    /// at the first round boundary past `ms` milliseconds after
    /// activation, saving a resumable checkpoint if a path is
    /// configured. `0` disables (the default).
    pub fn with_deadline_ms(mut self, ms: usize) -> JobSpec {
        self.exec = self.exec.with_deadline_ms(ms);
        self
    }

    /// QoS priority (0 = default). Higher-priority jobs drain first on
    /// the shared pool, and under overload the admission gate sheds
    /// lower-priority work to make room.
    pub fn with_priority(mut self, priority: usize) -> JobSpec {
        self.exec = self.exec.with_priority(priority);
        self
    }

    /// Where the deadline/drain path saves this job's checkpoint (see
    /// [`JobSpec::deadline_checkpoint`]).
    pub fn with_deadline_checkpoint(mut self, path: PathBuf) -> JobSpec {
        self.deadline_checkpoint = Some(path);
        self
    }

    /// The block tiling this job runs — derived from the embedded plan
    /// against the actual image geometry, exactly as the solo
    /// coordinator does, so identical specs tile identically on both
    /// paths.
    pub fn block_plan(&self) -> BlockPlan {
        self.exec.block_plan(self.dims.0, self.dims.1)
    }

    /// Reject malformed specs at submission time, before they occupy an
    /// admission slot's worth of pool work.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.cluster.k >= 1, "k must be at least 1");
        ensure!(
            self.pixels() >= self.cluster.k,
            "cannot init {} clusters from {} pixels",
            self.cluster.k,
            self.pixels()
        );
        if let IoMode::Strips { strip_rows, .. } = self.io {
            ensure!(strip_rows > 0, "strip_rows must be positive");
        }
        if self.input.is_streaming() {
            ensure!(
                matches!(self.io, IoMode::Strips { .. }),
                "streaming inputs (path/synthetic) require strip I/O"
            );
            ensure!(
                !matches!(self.cluster.init, InitMethod::PlusPlus),
                "k-means++ init needs the full image; streaming jobs use RandomSample"
            );
        }
        if self.share.is_some() {
            ensure!(
                matches!(self.input, JobInput::Raster(_)),
                "share groups need a resident raster (streaming jobs own their ingestion)"
            );
            ensure!(
                matches!(self.io, IoMode::Strips { .. }),
                "share groups amortize strip I/O; use IoMode::Strips"
            );
        }
        if self.deadline_checkpoint.is_some() {
            ensure!(
                self.mode == ClusterMode::Global,
                "deadline checkpoints need global mode (local runs are one round)"
            );
        }
        Ok(())
    }
}

/// Where a job is in its lifecycle.
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Accepted (admission slot held), not yet picked up by the serving
    /// loop.
    Queued,
    /// Rounds in flight on the shared pool.
    Running,
    /// Finished; the output is bit-identical to a solo
    /// [`crate::coordinator::Coordinator::cluster`] run of the same spec.
    Done(Box<ClusterOutput>),
    /// A worker error failed this job (other jobs unaffected).
    Failed(String),
    /// Cancelled before completion; partial work was discarded.
    Cancelled,
    /// The job's deadline (or the server's drain deadline) landed
    /// before it finished. When `checkpoint` is set, the last completed
    /// round boundary was saved there in the standard checkpoint format
    /// — resubmitting the same spec with
    /// [`JobSpec::with_resume`] continues bit-identically.
    Deadline { checkpoint: Option<PathBuf> },
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobStatus::Done(_)
                | JobStatus::Failed(_)
                | JobStatus::Cancelled
                | JobStatus::Deadline { .. }
        )
    }

    /// Short lifecycle label (stable across payload contents).
    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done(_) => "done",
            JobStatus::Failed(_) => "failed",
            JobStatus::Cancelled => "cancelled",
            JobStatus::Deadline { .. } => "deadline",
        }
    }
}

/// State shared between a [`JobHandle`] and the serving loop.
pub(crate) struct HandleShared {
    status: Mutex<JobStatus>,
    cond: Condvar,
    cancel: AtomicBool,
}

impl Default for HandleShared {
    fn default() -> HandleShared {
        HandleShared::new()
    }
}

impl HandleShared {
    pub(crate) fn new() -> HandleShared {
        HandleShared {
            status: Mutex::new(JobStatus::Queued),
            cond: Condvar::new(),
            cancel: AtomicBool::new(false),
        }
    }

    /// Serving-loop side: publish a status change.
    pub(crate) fn set_status(&self, status: JobStatus) {
        let mut st = self.status.lock().unwrap();
        *st = status;
        drop(st);
        self.cond.notify_all();
    }

    /// Serving-loop side: has the client asked to cancel?
    pub(crate) fn cancel_requested(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// QoS preemption: request cancellation without a [`JobHandle`]
    /// (the admission gate sheds the lowest-priority active job).
    pub(crate) fn request_cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// Client-side view of one submitted job.
#[derive(Clone)]
pub struct JobHandle {
    id: JobId,
    pub(crate) shared: Arc<HandleShared>,
}

impl JobHandle {
    pub(crate) fn new(id: JobId, shared: Arc<HandleShared>) -> JobHandle {
        JobHandle { id, shared }
    }

    /// The service-assigned job id (also tags the pool's messages).
    pub fn id(&self) -> JobId {
        self.id
    }

    /// Current status (non-blocking snapshot).
    pub fn status(&self) -> JobStatus {
        self.shared.status.lock().unwrap().clone()
    }

    /// Request cooperative cancellation. The serving loop stops issuing
    /// rounds for this job at the next outcome it routes; blocks already
    /// on workers finish and are discarded. Other jobs are untouched.
    /// Idempotent; a no-op once the job is terminal.
    pub fn cancel(&self) {
        self.shared.cancel.store(true, Ordering::Relaxed);
    }

    /// Block until the job reaches a terminal state; returns it.
    pub fn wait(&self) -> JobStatus {
        let mut st = self.shared.status.lock().unwrap();
        while !st.is_terminal() {
            st = self.shared.cond.wait(st).unwrap();
        }
        st.clone()
    }

    /// Block until terminal; `Ok` only for a completed job.
    pub fn wait_output(&self) -> Result<ClusterOutput> {
        match self.wait() {
            JobStatus::Done(out) => Ok(*out),
            JobStatus::Failed(msg) => bail!("job {} failed: {msg}", self.id),
            JobStatus::Cancelled => bail!("job {} was cancelled", self.id),
            JobStatus::Deadline { checkpoint: Some(p) } => bail!(
                "job {} hit its deadline; checkpoint written to {} (resume with the same spec)",
                self.id,
                p.display()
            ),
            JobStatus::Deadline { checkpoint: None } => {
                bail!("job {} hit its deadline; progress discarded", self.id)
            }
            JobStatus::Queued | JobStatus::Running => unreachable!("wait returns terminal states"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::image::SyntheticOrtho;

    fn spec(h: usize, w: usize) -> JobSpec {
        let img = Arc::new(SyntheticOrtho::default().with_seed(3).generate(h, w));
        JobSpec::new(
            img,
            ExecPlan::pinned(BlockShape::Square { side: 8 }),
            ClusterConfig::default(),
        )
    }

    #[test]
    fn valid_spec_passes() {
        assert!(spec(16, 16).validate().is_ok());
    }

    #[test]
    fn block_plan_follows_the_image() {
        // The old plan/image mismatch hazard is unrepresentable: the
        // tiling is derived from the exec plan against the image.
        let s = spec(16, 16);
        let plan = s.block_plan();
        assert_eq!((plan.height(), plan.width()), (16, 16));
        assert_eq!(plan.len(), 4);
    }

    #[test]
    fn zero_strip_rows_rejected() {
        let s = spec(16, 16).with_io(IoMode::Strips {
            strip_rows: 0,
            file_backed: false,
        });
        assert!(s.validate().is_err());
    }

    #[test]
    fn ppm_job_is_admitted_by_header_alone() {
        let img = SyntheticOrtho::default().with_seed(4).generate(24, 18);
        let dir = std::env::temp_dir().join("blockms_job_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("admit.ppm");
        crate::image::write_ppm(&img, &path).unwrap();
        let s = JobSpec::from_ppm(
            &path,
            ExecPlan::pinned(BlockShape::Square { side: 8 }),
            ClusterConfig::default(),
        )
        .unwrap();
        assert_eq!(s.dims(), (24, 18, 3));
        assert!(s.input.is_streaming());
        assert!(s.validate().is_ok());
        assert_eq!(s.block_plan().len(), 9);
        assert!(s.raster().is_none(), "no pixels resident at admission");
        // a missing file is a submission-time error, not a worker crash
        assert!(JobSpec::from_ppm(
            &dir.join("missing.ppm"),
            ExecPlan::default(),
            ClusterConfig::default()
        )
        .is_err());
    }

    #[test]
    fn streaming_spec_rejects_direct_io_and_plusplus() {
        let gen = SyntheticOrtho::default().with_seed(5);
        let s = JobSpec::from_synthetic(
            gen.clone(),
            16,
            16,
            ExecPlan::pinned(BlockShape::Square { side: 8 }),
            ClusterConfig::default(),
        );
        assert!(s.validate().is_ok());
        assert!(s.clone().with_io(IoMode::Direct).validate().is_err());
        let mut pp = s;
        pp.cluster.init = crate::kmeans::InitMethod::PlusPlus;
        assert!(pp.validate().is_err());
    }

    #[test]
    fn share_group_requires_raster_strips() {
        // direct I/O: nothing to share
        assert!(spec(16, 16).with_share_group(1).validate().is_err());
        // raster + strips: fine
        let ok = spec(16, 16)
            .with_io(IoMode::Strips {
                strip_rows: 8,
                file_backed: false,
            })
            .with_share_group(1);
        assert!(ok.validate().is_ok());
        // streaming inputs own their ingestion pass
        let gen = SyntheticOrtho::default().with_seed(5);
        let s = JobSpec::from_synthetic(
            gen,
            16,
            16,
            ExecPlan::pinned(BlockShape::Square { side: 8 }),
            ClusterConfig::default(),
        )
        .with_share_group(1);
        assert!(s.validate().is_err());
    }

    #[test]
    fn handle_status_transitions_and_wait() {
        let shared = Arc::new(HandleShared::new());
        let h = JobHandle::new(7, Arc::clone(&shared));
        assert_eq!(h.status().label(), "queued");
        assert!(!shared.cancel_requested());
        h.cancel();
        assert!(shared.cancel_requested());
        let waiter = {
            let h = h.clone();
            std::thread::spawn(move || h.wait().label())
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        shared.set_status(JobStatus::Cancelled);
        assert_eq!(waiter.join().unwrap(), "cancelled");
        assert!(h.wait_output().is_err());
    }
}
