//! Admission control: a bounded in-flight-jobs gate with backpressure.
//!
//! The service keeps at most `cap` clustering jobs open at once. A
//! blocking [`Admission::acquire`] is the backpressure path (callers of
//! `submit` wait their turn); [`Admission::try_acquire`] is the
//! load-shedding path (callers of `try_submit` get an immediate
//! "busy"). The gate records a high-water mark so tests can assert the
//! cap was *never* exceeded, not merely that it holds at sample points.

use std::sync::{Condvar, Mutex};

/// Point-in-time view of the gate (all counters monotone except
/// `in_flight`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmissionSnapshot {
    /// Maximum jobs admitted at once.
    pub cap: usize,
    /// Currently admitted (acquired, not yet released).
    pub in_flight: usize,
    /// Highest `in_flight` ever observed.
    pub high_water: usize,
    /// Total successful acquisitions.
    pub admitted: u64,
    /// Total `try_acquire` rejections.
    pub rejected: u64,
}

struct GateState {
    in_flight: usize,
    high_water: usize,
    admitted: u64,
    rejected: u64,
}

/// The gate. Cheap to share behind an `Arc`.
pub struct Admission {
    cap: usize,
    state: Mutex<GateState>,
    cond: Condvar,
}

impl Admission {
    pub fn new(cap: usize) -> Admission {
        assert!(cap > 0, "admission cap must be at least 1");
        Admission {
            cap,
            state: Mutex::new(GateState {
                in_flight: 0,
                high_water: 0,
                admitted: 0,
                rejected: 0,
            }),
            cond: Condvar::new(),
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Block until a slot frees up, then take it.
    pub fn acquire(&self) {
        let mut st = self.state.lock().unwrap();
        while st.in_flight >= self.cap {
            st = self.cond.wait(st).unwrap();
        }
        st.in_flight += 1;
        st.admitted += 1;
        st.high_water = st.high_water.max(st.in_flight);
    }

    /// Take a slot if one is free; `false` means the gate is full.
    pub fn try_acquire(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.in_flight >= self.cap {
            st.rejected += 1;
            return false;
        }
        st.in_flight += 1;
        st.admitted += 1;
        st.high_water = st.high_water.max(st.in_flight);
        true
    }

    /// Return a slot (job reached a terminal state) and wake one waiter.
    pub fn release(&self) {
        let mut st = self.state.lock().unwrap();
        assert!(st.in_flight > 0, "release without acquire");
        st.in_flight -= 1;
        drop(st);
        self.cond.notify_one();
    }

    pub fn snapshot(&self) -> AdmissionSnapshot {
        let st = self.state.lock().unwrap();
        AdmissionSnapshot {
            cap: self.cap,
            in_flight: st.in_flight,
            high_water: st.high_water,
            admitted: st.admitted,
            rejected: st.rejected,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn try_acquire_respects_cap() {
        let gate = Admission::new(2);
        assert!(gate.try_acquire());
        assert!(gate.try_acquire());
        assert!(!gate.try_acquire());
        let snap = gate.snapshot();
        assert_eq!(snap.in_flight, 2);
        assert_eq!(snap.rejected, 1);
        gate.release();
        assert!(gate.try_acquire());
        assert_eq!(gate.snapshot().high_water, 2);
    }

    #[test]
    fn acquire_blocks_until_release() {
        let gate = Arc::new(Admission::new(1));
        gate.acquire();
        let g2 = Arc::clone(&gate);
        let h = std::thread::spawn(move || {
            g2.acquire(); // blocks until main releases
            g2.snapshot().in_flight
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(gate.snapshot().in_flight, 1, "waiter must not be admitted");
        gate.release();
        assert_eq!(h.join().unwrap(), 1);
        assert_eq!(gate.snapshot().high_water, 1, "cap 1 never exceeded");
    }

    #[test]
    fn high_water_tracks_concurrency_exactly() {
        let gate = Arc::new(Admission::new(3));
        let mut handles = Vec::new();
        for _ in 0..10 {
            let g = Arc::clone(&gate);
            handles.push(std::thread::spawn(move || {
                g.acquire();
                std::thread::sleep(std::time::Duration::from_millis(2));
                g.release();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = gate.snapshot();
        assert_eq!(snap.admitted, 10);
        assert_eq!(snap.in_flight, 0);
        assert!(snap.high_water <= 3, "cap exceeded: {}", snap.high_water);
        assert!(snap.high_water >= 1);
    }

    #[test]
    #[should_panic(expected = "release without acquire")]
    fn release_without_acquire_panics() {
        Admission::new(1).release();
    }
}
