//! The persistent multi-job clustering service.
//!
//! The paper runs one K-Means over one image with a pool that is built
//! and torn down around the run. Production traffic is many concurrent
//! clustering requests, so this layer decouples the pool from the run:
//!
//! - [`ClusterServer`] — spawns one [`crate::coordinator::WorkerPool`]
//!   and serves any number of jobs over it, interleaving blocks from
//!   different images on the same workers;
//! - [`JobSpec`] / [`JobHandle`] / [`JobStatus`] — per-job description
//!   (each job has its own k, channels, block plan, I/O mode, and
//!   compute kernel) and lifecycle
//!   (`Queued → Running → Done | Failed | Cancelled | Deadline`);
//! - [`Admission`] — the bounded in-flight gate: `submit` blocks when
//!   full (backpressure), `try_submit` sheds — lowest-priority work
//!   first when the incoming job outranks an open one (QoS);
//! - [`DrainReport`] — [`ClusterServer::drain`]'s per-job disposition
//!   audit: every open job finishes, checkpoints, or is cancelled by a
//!   deadline, never silently lost.
//!
//! **Determinism contract:** a job run through the shared pool produces
//! labels, centroids, counts, and inertia bit-identical to a solo
//! [`crate::coordinator::Coordinator::cluster`] with the same spec and
//! seed, no matter what else is in flight — enforced by
//! `tests/service_concurrency.rs` across k, channel counts, block
//! shapes, and kernels. See EXPERIMENTS.md §Service for the
//! architecture sketch and the `BENCH_service.json` throughput
//! methodology (`blockms batch` / `blockms serve`).

mod admission;
mod job;
mod server;

pub use admission::{Admission, AdmissionSnapshot};
pub use job::{JobHandle, JobInput, JobSpec, JobStatus};
pub use server::{ClusterServer, DrainReport, ServerConfig, ServerStats};
