//! PPM/PGM codecs — write the Figures 3–7 analogues to disk.
//!
//! Binary `P6` (RGB) and `P5` (gray) only; that is all the examples need
//! to dump input scenes and clustered label maps for visual inspection.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::raster::Raster;

/// A qualitative palette for label maps (distinct hues, ordered).
pub const PALETTE: [[u8; 3]; 10] = [
    [230, 25, 75],
    [60, 180, 75],
    [0, 130, 200],
    [255, 225, 25],
    [145, 30, 180],
    [70, 240, 240],
    [245, 130, 48],
    [240, 50, 230],
    [128, 128, 0],
    [0, 0, 128],
];

/// Write an RGB (or gray, replicated) raster as binary PPM. Samples are
/// clamped to `[0, 255]` and truncated to u8.
pub fn write_ppm(img: &Raster, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "P6\n{} {}\n255", img.width(), img.height())?;
    let c = img.channels();
    let mut buf = Vec::with_capacity(img.width() * 3);
    for r in 0..img.height() {
        buf.clear();
        for col in 0..img.width() {
            let px = img.get(r, col);
            for b in 0..3 {
                let v = px[b.min(c - 1)].clamp(0.0, 255.0) as u8;
                buf.push(v);
            }
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Write a label map (`labels[row*width+col]`) as a palette-colored PPM.
pub fn write_labels_ppm(labels: &[u32], height: usize, width: usize, path: &Path) -> Result<()> {
    if labels.len() != height * width {
        bail!(
            "label buffer {} != {}x{}",
            labels.len(),
            height,
            width
        );
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "P6\n{width} {height}\n255")?;
    let mut buf = Vec::with_capacity(width * 3);
    for r in 0..height {
        buf.clear();
        for c in 0..width {
            let l = labels[r * width + c] as usize % PALETTE.len();
            buf.extend_from_slice(&PALETTE[l]);
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Write a label map as grayscale PGM (`label * 255 / (k-1)`).
pub fn write_labels_pgm(
    labels: &[u32],
    height: usize,
    width: usize,
    k: usize,
    path: &Path,
) -> Result<()> {
    if labels.len() != height * width {
        bail!("label buffer {} != {}x{}", labels.len(), height, width);
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "P5\n{width} {height}\n255")?;
    let scale = if k > 1 { 255 / (k - 1) as u32 } else { 255 };
    let mut buf = Vec::with_capacity(width);
    for r in 0..height {
        buf.clear();
        for c in 0..width {
            buf.push((labels[r * width + c] * scale).min(255) as u8);
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// A parsed binary-pixmap header. One parser serves every consumer —
/// [`read_ppm`], [`ppm_dims`], and the streaming
/// [`crate::image::PpmSource`] — so magic/whitespace/comment/maxval
/// handling cannot drift between the whole-file and strip decoders.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PpmHeader {
    pub height: usize,
    pub width: usize,
    /// Always ≤ 255: one byte per sample (16-bit pixmaps are rejected).
    pub maxval: usize,
}

impl PpmHeader {
    /// Channel count of the P6 payload (always RGB).
    pub fn channels(&self) -> usize {
        3
    }

    /// Payload bytes one image row occupies.
    pub fn row_bytes(&self) -> usize {
        self.width * 3
    }
}

/// Read only a PPM's header: `(height, width, channels)`. The pixel
/// payload is never touched — this is what `blockms cluster --dry-run`
/// and `blockms plan` use to plan against a real file without paying
/// for its pixels.
pub fn ppm_dims(path: &Path) -> Result<(usize, usize, usize)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let h = read_header(&mut r)?;
    Ok((h.height, h.width, h.channels()))
}

/// Parse the P6 header up to (and including) maxval; leaves the reader
/// at the first payload byte. `#` comments may appear anywhere in the
/// header; a maxval of 0 or above 255 (e.g. 16-bit 65535) is rejected.
pub(super) fn read_header<R: BufRead>(r: &mut R) -> Result<PpmHeader> {
    let magic = read_token(r)?;
    if magic != "P6" {
        bail!("unsupported magic {magic:?} (want P6)");
    }
    let width: usize = read_token(r)?.parse().context("width")?;
    let height: usize = read_token(r)?.parse().context("height")?;
    let maxval: usize = read_token(r)?.parse().context("maxval")?;
    if maxval == 0 || maxval > 255 {
        bail!("unsupported maxval {maxval}");
    }
    if width == 0 || height == 0 {
        bail!("degenerate image {width}x{height}");
    }
    Ok(PpmHeader {
        height,
        width,
        maxval,
    })
}

/// Read a binary PPM (P6, maxval ≤ 255) into an RGB raster.
pub fn read_ppm(path: &Path) -> Result<Raster> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let h = read_header(&mut r)?;
    let mut raw = vec![0u8; h.width * h.height * 3];
    r.read_exact(&mut raw).context("pixel payload")?;
    let data: Vec<f32> = raw.iter().map(|&b| b as f32).collect();
    Ok(Raster::from_vec(h.height, h.width, 3, data))
}

/// Read one whitespace-delimited header token, skipping `#` comments.
fn read_token<R: BufRead>(r: &mut R) -> Result<String> {
    let mut tok = String::new();
    let mut in_comment = false;
    loop {
        let mut byte = [0u8; 1];
        if r.read(&mut byte)? == 0 {
            bail!("unexpected EOF in header");
        }
        let c = byte[0] as char;
        if in_comment {
            if c == '\n' {
                in_comment = false;
            }
            continue;
        }
        if c == '#' {
            in_comment = true;
            continue;
        }
        if c.is_whitespace() {
            if tok.is_empty() {
                continue;
            }
            return Ok(tok);
        }
        tok.push(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::SyntheticOrtho;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("blockms_ppm_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn dims_reads_header_only() {
        let img = SyntheticOrtho::default().with_seed(9).generate(20, 30);
        let path = tmp("dims.ppm");
        write_ppm(&img, &path).unwrap();
        assert_eq!(ppm_dims(&path).unwrap(), (20, 30, 3));
        // even with the payload truncated away, the header still reads
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..15.min(bytes.len())]).unwrap();
        assert!(read_ppm(&path).is_err(), "payload is gone");
        assert_eq!(ppm_dims(&path).unwrap(), (20, 30, 3));
    }

    #[test]
    fn ppm_round_trip() {
        let img = SyntheticOrtho::default().with_seed(9).generate(20, 30);
        let path = tmp("rt.ppm");
        write_ppm(&img, &path).unwrap();
        let back = read_ppm(&path).unwrap();
        assert_eq!(back.height(), 20);
        assert_eq!(back.width(), 30);
        // u8 quantization: within 1 DN
        for r in 0..20 {
            for c in 0..30 {
                for b in 0..3 {
                    let a = img.get(r, c)[b];
                    let z = back.get(r, c)[b];
                    assert!((a - z).abs() <= 1.0, "({r},{c},{b}): {a} vs {z}");
                }
            }
        }
    }

    #[test]
    fn labels_ppm_writes() {
        let labels: Vec<u32> = (0..12).map(|i| i % 4).collect();
        let path = tmp("labels.ppm");
        write_labels_ppm(&labels, 3, 4, &path).unwrap();
        let img = read_ppm(&path).unwrap();
        assert_eq!(img.get(0, 0), &[230.0, 25.0, 75.0]); // PALETTE[0]
        assert_eq!(img.get(0, 1), &[60.0, 180.0, 75.0]); // PALETTE[1]
    }

    #[test]
    fn labels_pgm_writes() {
        let labels: Vec<u32> = vec![0, 1, 1, 0];
        let path = tmp("labels.pgm");
        write_labels_pgm(&labels, 2, 2, 2, &path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert!(raw.starts_with(b"P5"));
        assert_eq!(&raw[raw.len() - 4..], &[0u8, 255, 255, 0]);
    }

    #[test]
    fn label_len_mismatch_errors() {
        assert!(write_labels_ppm(&[0u32; 5], 2, 3, &tmp("bad.ppm")).is_err());
    }

    #[test]
    fn read_rejects_bad_magic() {
        let path = tmp("badmagic.ppm");
        std::fs::write(&path, b"P3\n1 1\n255\n0 0 0\n").unwrap();
        assert!(read_ppm(&path).is_err());
    }

    #[test]
    fn header_comments_are_skipped() {
        let path = tmp("comment.ppm");
        std::fs::write(&path, b"P6 # comment\n# full line\n2 1\n255\nabcdef").unwrap();
        let img = read_ppm(&path).unwrap();
        assert_eq!(img.width(), 2);
        assert_eq!(img.get(0, 0)[0], b'a' as f32);
        // the shared parser serves ppm_dims the same view
        assert_eq!(ppm_dims(&path).unwrap(), (1, 2, 3));
    }

    #[test]
    fn truncated_header_is_clean_error_everywhere() {
        // Cut inside the height token: every consumer of the shared
        // parser must fail, not hang or panic.
        let path = tmp("trunc.ppm");
        std::fs::write(&path, b"P6\n10 1").unwrap();
        assert!(read_ppm(&path).is_err());
        assert!(ppm_dims(&path).is_err());
    }

    #[test]
    fn maxval_zero_and_16bit_rejected() {
        for (name, maxval) in [("max0.ppm", "0"), ("max16.ppm", "65536"), ("max65535.ppm", "65535")]
        {
            let path = tmp(name);
            std::fs::write(&path, format!("P6\n1 1\n{maxval}\nabc")).unwrap();
            let err = ppm_dims(&path).unwrap_err();
            assert!(
                format!("{err:#}").contains("maxval"),
                "{name}: wrong error {err:#}"
            );
            assert!(read_ppm(&path).is_err(), "{name}");
        }
        // maxval 255 and 1 are fine
        let path = tmp("max255.ppm");
        std::fs::write(&path, b"P6\n1 1\n255\nabc").unwrap();
        assert_eq!(ppm_dims(&path).unwrap(), (1, 1, 3));
        let path = tmp("max1.ppm");
        std::fs::write(&path, b"P6\n1 1\n1\n\0\0\0").unwrap();
        assert_eq!(ppm_dims(&path).unwrap(), (1, 1, 3));
    }

    #[test]
    fn non_numeric_and_zero_dims_rejected() {
        let path = tmp("badw.ppm");
        std::fs::write(&path, b"P6\nten 10\n255\n").unwrap();
        assert!(ppm_dims(&path).is_err());
        let path = tmp("zerow.ppm");
        std::fs::write(&path, b"P6\n0 10\n255\n").unwrap();
        assert!(ppm_dims(&path).is_err());
    }
}
