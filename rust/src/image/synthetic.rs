//! Synthetic orthoimagery generator (the paper's dataset substitute).
//!
//! The paper uses 100+ USGS EarthExplorer aerial images (RGB, 8/16-bit,
//! 30–80 cm GSD, 1024×768 … 9052×4965 px). We can't ship those, so this
//! generator produces scenes with the properties that actually matter to
//! K-Means timing and clustering behaviour:
//!
//! - **spatially coherent structure** — multi-octave value noise
//!   ("terrain") so blocks are not i.i.d. and block-local clustering
//!   differs from global clustering, as on real scenes;
//! - **distinct land-cover classes** — `classes` spectral signatures
//!   (think water / vegetation / bare soil / built-up) blended by a
//!   second noise field, so K-Means at the paper's K ∈ {2,4} finds real
//!   structure;
//! - **sensor noise** — per-band Gaussian noise at `noise_dn` DNs;
//! - **8-bit DN range** `[0, 255]`, matching the paper's medium-res set.
//!
//! Generation is deterministic in the seed and O(pixels).

use super::raster::Raster;
use crate::util::prng::Rng;

/// Configuration for the synthetic scene generator.
#[derive(Clone, Debug)]
pub struct SyntheticOrtho {
    /// Land-cover class count (spectral clusters genuinely present).
    pub classes: usize,
    /// Octaves of value noise for the class field.
    pub octaves: usize,
    /// Base lattice cell size in pixels at the coarsest octave.
    pub base_cell: usize,
    /// Std-dev of per-band sensor noise, in DNs.
    pub noise_dn: f32,
    /// Output band count (3 = RGB, the paper's imagery).
    pub channels: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for SyntheticOrtho {
    fn default() -> Self {
        Self {
            classes: 5,
            octaves: 4,
            base_cell: 256,
            noise_dn: 6.0,
            channels: 3,
            seed: 0xB10C_5EED,
        }
    }
}

impl SyntheticOrtho {
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_classes(mut self, classes: usize) -> Self {
        assert!(classes >= 2, "need at least 2 land-cover classes");
        self.classes = classes;
        self
    }

    pub fn with_channels(mut self, channels: usize) -> Self {
        assert!((1..=4).contains(&channels));
        self.channels = channels;
        self
    }

    /// Generate a `height×width` scene.
    pub fn generate(&self, height: usize, width: usize) -> Raster {
        self.generate_with_truth(height, width).0
    }

    /// Open a row-streaming generator over the same deterministic draw
    /// as [`SyntheticOrtho::generate`] — the out-of-core ingestion path
    /// pulls strips from it without the whole scene ever being resident.
    /// [`SyntheticOrtho::generate_with_truth`] is built on this stream,
    /// so the two are bit-identical by construction.
    pub fn stream(&self, height: usize, width: usize) -> SyntheticStream {
        assert!(height > 0 && width > 0);
        let mut rng = Rng::new(self.seed);

        // Spectral signature per class per band, spread over the DN range
        // so classes are separable but overlapping (realistic confusion).
        let mut signatures = vec![vec![0.0f32; self.channels]; self.classes];
        for (ci, sig) in signatures.iter_mut().enumerate() {
            let base = 30.0 + 195.0 * (ci as f32 + 0.5) / self.classes as f32;
            for s in sig.iter_mut() {
                *s = (base + (rng.next_f32() - 0.5) * 60.0).clamp(0.0, 255.0);
            }
        }

        // Per-octave permutation-hash lattices (value noise). Noise is
        // evaluated per pixel from hashed lattice corners with bilinear
        // interpolation — O(1) per pixel per octave, no stored lattice.
        let field_seed = rng.split();
        let noise_rng = rng.split();

        SyntheticStream {
            cfg: self.clone(),
            height,
            width,
            signatures,
            field_seed,
            noise_rng,
            class_row: vec![0.0; width],
            next_row: 0,
        }
    }

    /// Generate a scene plus its ground-truth land-cover map (the class
    /// index each pixel was rendered from). The truth map is what the
    /// clustering *should* recover (up to label permutation) — used by
    /// [`crate::metrics::quality`] to score clusterings objectively.
    pub fn generate_with_truth(&self, height: usize, width: usize) -> (Raster, Vec<u32>) {
        let mut stream = self.stream(height, width);
        let mut data = Vec::with_capacity(height * width * self.channels);
        let mut truth = Vec::with_capacity(height * width);
        while stream.next_rows(height, &mut data, Some(&mut truth)) > 0 {}
        (Raster::from_vec(height, width, self.channels, data), truth)
    }

    /// Evaluate the multi-octave class field for one row into `out`
    /// (values in [0,1)).
    fn class_field_row(&self, seed: &Rng, row: usize, out: &mut [f32]) {
        let base_seed = {
            // Derive a stable u64 from the split-off generator's state by
            // cloning (the clone is never advanced, so this is pure).
            let mut s = seed.clone();
            s.next_u64()
        };
        out.iter_mut().for_each(|v| *v = 0.0);
        let mut amp = 1.0f32;
        let mut total_amp = 0.0f32;
        let mut cell = self.base_cell.max(2);
        for oct in 0..self.octaves {
            let oseed = base_seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(oct as u64 + 1));
            let y = row as f32 / cell as f32;
            let y0 = y.floor() as i64;
            let fy = y - y0 as f32;
            let sy = smooth(fy);
            for (c, v) in out.iter_mut().enumerate() {
                let x = c as f32 / cell as f32;
                let x0 = x.floor() as i64;
                let fx = x - x0 as f32;
                let sx = smooth(fx);
                let v00 = lattice(oseed, x0, y0);
                let v10 = lattice(oseed, x0 + 1, y0);
                let v01 = lattice(oseed, x0, y0 + 1);
                let v11 = lattice(oseed, x0 + 1, y0 + 1);
                let a = v00 * (1.0 - sx) + v10 * sx;
                let b = v01 * (1.0 - sx) + v11 * sx;
                *v += (a * (1.0 - sy) + b * sy) * amp;
            }
            total_amp += amp;
            amp *= 0.55;
            cell = (cell / 2).max(2);
        }
        for v in out.iter_mut() {
            *v = (*v / total_amp).clamp(0.0, 0.999_999);
        }
    }
}

/// A row cursor over one synthetic scene. Holds O(width) state — the
/// class-field row buffer plus the two PRNG streams — and emits rows in
/// order, exactly the sequence [`SyntheticOrtho::generate`] would have
/// produced (the generator is built on this stream, so identity is by
/// construction, and a test pins it).
pub struct SyntheticStream {
    cfg: SyntheticOrtho,
    height: usize,
    width: usize,
    signatures: Vec<Vec<f32>>,
    field_seed: Rng,
    noise_rng: Rng,
    class_row: Vec<f32>,
    next_row: usize,
}

impl SyntheticStream {
    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn channels(&self) -> usize {
        self.cfg.channels
    }

    /// Rows not yet emitted.
    pub fn rows_remaining(&self) -> usize {
        self.height - self.next_row
    }

    /// Emit up to `max_rows` rows: interleaved samples appended to
    /// `out_px`, ground-truth classes to `out_truth` when asked for.
    /// Returns the number of rows emitted (0 once the scene is done).
    pub fn next_rows(
        &mut self,
        max_rows: usize,
        out_px: &mut Vec<f32>,
        mut out_truth: Option<&mut Vec<u32>>,
    ) -> usize {
        let rows = max_rows.min(self.rows_remaining());
        let inv_classes = self.cfg.classes as f32;
        for r in self.next_row..self.next_row + rows {
            self.cfg
                .class_field_row(&self.field_seed, r, &mut self.class_row);
            for c in 0..self.width {
                // continuous class value in [0, classes)
                let t = (self.class_row[c] * inv_classes).min(inv_classes - 1e-3);
                let lo = t.floor() as usize;
                let hi = (lo + 1).min(self.cfg.classes - 1);
                let frac = t - lo as f32;
                if let Some(truth) = out_truth.as_deref_mut() {
                    truth.push(if frac < 0.5 { lo as u32 } else { hi as u32 });
                }
                for b in 0..self.cfg.channels {
                    let v =
                        self.signatures[lo][b] * (1.0 - frac) + self.signatures[hi][b] * frac;
                    let n = self.noise_rng.next_gauss() as f32 * self.cfg.noise_dn;
                    out_px.push((v + n).clamp(0.0, 255.0));
                }
            }
        }
        self.next_row += rows;
        rows
    }
}

/// Smoothstep for bilinear noise interpolation.
#[inline]
fn smooth(t: f32) -> f32 {
    t * t * (3.0 - 2.0 * t)
}

/// Hash a lattice point to a uniform f32 in [0,1) (splitmix-style mix).
#[inline]
fn lattice(seed: u64, x: i64, y: i64) -> f32 {
    let mut z = seed
        .wrapping_add((x as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add((y as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f32 * (1.0 / (1u64 << 53) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let g = SyntheticOrtho::default().with_seed(42);
        let a = g.generate(64, 80);
        let b = g.generate(64, 80);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SyntheticOrtho::default().with_seed(1).generate(32, 32);
        let b = SyntheticOrtho::default().with_seed(2).generate(32, 32);
        assert_ne!(a, b);
    }

    #[test]
    fn dn_range_is_8bit() {
        let img = SyntheticOrtho::default().with_seed(3).generate(100, 120);
        let s = img.stats();
        for b in 0..img.channels() {
            assert!(s.min[b] >= 0.0 && s.max[b] <= 255.0);
        }
        // scene should actually use a good part of the range
        assert!(s.max[0] - s.min[0] > 60.0, "flat scene: {:?}", s);
    }

    #[test]
    fn has_spatial_structure() {
        // Neighbouring pixels must correlate far more than distant ones —
        // i.i.d. noise would make block-shape analysis meaningless.
        // Sensor noise off: this probes the class *field*'s coherence.
        let img = SyntheticOrtho {
            noise_dn: 0.0,
            ..Default::default()
        }
        .with_seed(4)
        .generate(128, 128);
        let mut near = 0.0f64;
        let mut far = 0.0f64;
        let n = 127;
        for r in 0..n {
            let a = img.get(r, 10)[0] as f64;
            near += (a - img.get(r + 1, 10)[0] as f64).abs();
            far += (a - img.get(r, 110)[0] as f64).abs();
        }
        assert!(
            near / n as f64 * 2.0 < far / n as f64,
            "no spatial coherence: near={near} far={far}"
        );
    }

    #[test]
    fn classes_are_separable() {
        // A 2-means on a 4-class scene must find a split with lower
        // inertia than the global variance (i.e. real cluster structure).
        let img = SyntheticOrtho::default().with_seed(5).generate(96, 96);
        let px = img.as_pixels();
        let c = img.channels();
        let n = img.pixels();
        // global variance around the mean
        let stats = img.stats();
        let mut var = 0.0f64;
        for p in px.chunks_exact(c) {
            for (b, &v) in p.iter().enumerate() {
                let d = v as f64 - stats.mean[b];
                var += d * d;
            }
        }
        // crude 2-means: split on band-0 threshold at the mean
        let thr = stats.mean[0] as f32;
        let (mut lo, mut hi) = (vec![0.0f64; c], vec![0.0f64; c]);
        let (mut nlo, mut nhi) = (0usize, 0usize);
        for p in px.chunks_exact(c) {
            if p[0] < thr {
                for b in 0..c {
                    lo[b] += p[b] as f64;
                }
                nlo += 1;
            } else {
                for b in 0..c {
                    hi[b] += p[b] as f64;
                }
                nhi += 1;
            }
        }
        assert!(nlo > n / 20 && nhi > n / 20, "degenerate split {nlo}/{nhi}");
        for b in 0..c {
            lo[b] /= nlo as f64;
            hi[b] /= nhi as f64;
        }
        let mut within = 0.0f64;
        for p in px.chunks_exact(c) {
            let m = if p[0] < thr { &lo } else { &hi };
            for (b, &v) in p.iter().enumerate() {
                let d = v as f64 - m[b];
                within += d * d;
            }
        }
        assert!(
            within < 0.8 * var,
            "no class structure: within={within:.1} var={var:.1}"
        );
    }

    #[test]
    fn stream_in_any_strip_size_equals_generate() {
        let g = SyntheticOrtho::default().with_seed(77);
        let whole = g.generate(33, 21);
        for strip in [1usize, 4, 7, 33, 50] {
            let mut s = g.stream(33, 21);
            let mut px = Vec::new();
            let mut truth = Vec::new();
            let mut rows = 0;
            loop {
                let n = s.next_rows(strip, &mut px, Some(&mut truth));
                if n == 0 {
                    break;
                }
                rows += n;
            }
            assert_eq!(rows, 33, "strip={strip}");
            assert_eq!(px, whole.data(), "strip={strip}: pixels diverged");
            assert_eq!(truth.len(), 33 * 21);
            assert_eq!(s.rows_remaining(), 0);
        }
    }

    #[test]
    fn stream_without_truth_is_still_identical() {
        // Truth extraction consumes no randomness: skipping it must not
        // perturb the pixel stream.
        let g = SyntheticOrtho::default().with_seed(78);
        let whole = g.generate(16, 9);
        let mut s = g.stream(16, 9);
        let mut px = Vec::new();
        while s.next_rows(5, &mut px, None) > 0 {}
        assert_eq!(px, whole.data());
    }

    #[test]
    fn channel_count_respected() {
        let img = SyntheticOrtho::default().with_channels(1).generate(16, 16);
        assert_eq!(img.channels(), 1);
        let img4 = SyntheticOrtho::default().with_channels(4).generate(16, 16);
        assert_eq!(img4.channels(), 4);
    }
}
