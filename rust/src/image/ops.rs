//! Raster preprocessing operations.
//!
//! The pipeline stages a real satellite-image workflow needs before
//! clustering: grayscale conversion, band normalization, downsampling
//! (the paper works across 30–80 cm GSD resolutions — downsampling
//! emulates coarser GSD), histograms, and bit-depth rescaling (the
//! paper mixes 8-bit and 16-bit imagery).

use super::raster::Raster;

/// Luma grayscale (Rec.601 weights for RGB; mean for other band counts).
pub fn to_gray(img: &Raster) -> Raster {
    let c = img.channels();
    let mut out = Raster::zeros(img.height(), img.width(), 1);
    let weights: &[f32] = if c == 3 {
        &[0.299, 0.587, 0.114]
    } else {
        &[]
    };
    for (dst, px) in out
        .data_mut()
        .iter_mut()
        .zip(img.data().chunks_exact(c))
    {
        *dst = if c == 3 {
            px.iter().zip(weights).map(|(v, w)| v * w).sum()
        } else {
            px.iter().sum::<f32>() / c as f32
        };
    }
    out
}

/// Per-band min-max normalization to `[0, hi]`.
pub fn normalize(img: &Raster, hi: f32) -> Raster {
    assert!(hi > 0.0);
    let stats = img.stats();
    let c = img.channels();
    let mut out = img.clone();
    let scale: Vec<f32> = (0..c)
        .map(|b| {
            let range = stats.max[b] - stats.min[b];
            if range > 0.0 {
                hi / range
            } else {
                0.0
            }
        })
        .collect();
    for px in out.data_mut().chunks_exact_mut(c) {
        for (b, v) in px.iter_mut().enumerate() {
            *v = (*v - stats.min[b]) * scale[b];
        }
    }
    out
}

/// Rescale a 16-bit DN range (`[0, 65535]`) to 8-bit (`[0, 255]`) — the
/// paper's high-resolution set is 16-bit.
pub fn rescale_16_to_8(img: &Raster) -> Raster {
    let mut out = img.clone();
    for v in out.data_mut() {
        *v = (*v / 257.0).clamp(0.0, 255.0);
    }
    out
}

/// Box-filter downsample by integer `factor` (GSD coarsening).
/// Edge cells average the available pixels.
pub fn downsample(img: &Raster, factor: usize) -> Raster {
    assert!(factor >= 1);
    if factor == 1 {
        return img.clone();
    }
    let c = img.channels();
    let oh = img.height().div_ceil(factor);
    let ow = img.width().div_ceil(factor);
    let mut out = Raster::zeros(oh, ow, c);
    for orow in 0..oh {
        for ocol in 0..ow {
            let r0 = orow * factor;
            let c0 = ocol * factor;
            let r1 = (r0 + factor).min(img.height());
            let c1 = (c0 + factor).min(img.width());
            let mut acc = vec![0.0f64; c];
            for r in r0..r1 {
                for col in c0..c1 {
                    for (b, &v) in img.get(r, col).iter().enumerate() {
                        acc[b] += v as f64;
                    }
                }
            }
            let n = ((r1 - r0) * (c1 - c0)) as f64;
            let px: Vec<f32> = acc.iter().map(|a| (a / n) as f32).collect();
            out.set(orow, ocol, &px);
        }
    }
    out
}

/// Per-band histogram with `bins` buckets over `[lo, hi)`.
/// Returns `channels × bins` counts.
pub fn histogram(img: &Raster, bins: usize, lo: f32, hi: f32) -> Vec<Vec<u64>> {
    assert!(bins > 0 && hi > lo);
    let c = img.channels();
    let mut out = vec![vec![0u64; bins]; c];
    let scale = bins as f32 / (hi - lo);
    for px in img.data().chunks_exact(c) {
        for (b, &v) in px.iter().enumerate() {
            let bin = (((v - lo) * scale) as isize).clamp(0, bins as isize - 1) as usize;
            out[b][bin] += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::SyntheticOrtho;

    fn img() -> Raster {
        SyntheticOrtho::default().with_seed(31).generate(24, 32)
    }

    #[test]
    fn gray_has_one_band_and_rec601_weights() {
        let mut src = Raster::zeros(1, 1, 3);
        src.set(0, 0, &[100.0, 200.0, 50.0]);
        let g = to_gray(&src);
        assert_eq!(g.channels(), 1);
        let want = 100.0 * 0.299 + 200.0 * 0.587 + 50.0 * 0.114;
        assert!((g.get(0, 0)[0] - want).abs() < 1e-4);
    }

    #[test]
    fn normalize_hits_full_range() {
        let n = normalize(&img(), 1.0);
        let s = n.stats();
        for b in 0..3 {
            assert!(s.min[b].abs() < 1e-6);
            assert!((s.max[b] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn normalize_flat_band_is_zero() {
        let flat = Raster::zeros(4, 4, 1);
        let n = normalize(&flat, 255.0);
        assert!(n.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn rescale_16bit_maxes_at_255() {
        let mut src = Raster::zeros(1, 2, 1);
        src.set(0, 0, &[65535.0]);
        src.set(0, 1, &[32767.5]);
        let out = rescale_16_to_8(&src);
        assert!((out.get(0, 0)[0] - 255.0).abs() < 0.01);
        assert!((out.get(0, 1)[0] - 127.5).abs() < 0.05);
    }

    #[test]
    fn downsample_halves_dims_and_preserves_mean() {
        let src = img();
        let d = downsample(&src, 2);
        assert_eq!(d.height(), 12);
        assert_eq!(d.width(), 16);
        let m_src = src.stats().mean[0];
        let m_d = d.stats().mean[0];
        assert!((m_src - m_d).abs() < 1.0, "{m_src} vs {m_d}");
    }

    #[test]
    fn downsample_uneven_edges() {
        let src = SyntheticOrtho::default().with_seed(1).generate(5, 7);
        let d = downsample(&src, 3);
        assert_eq!((d.height(), d.width()), (2, 3));
    }

    #[test]
    fn downsample_identity_at_factor_1() {
        let src = img();
        assert_eq!(downsample(&src, 1), src);
    }

    #[test]
    fn histogram_counts_every_pixel() {
        let h = histogram(&img(), 16, 0.0, 256.0);
        assert_eq!(h.len(), 3);
        for band in &h {
            assert_eq!(band.iter().sum::<u64>() as usize, 24 * 32);
        }
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut src = Raster::zeros(1, 2, 1);
        src.set(0, 0, &[-5.0]);
        src.set(0, 1, &[999.0]);
        let h = histogram(&src, 4, 0.0, 100.0);
        assert_eq!(h[0][0], 1);
        assert_eq!(h[0][3], 1);
    }
}
