//! Image substrate: rasters, synthetic orthoimagery, codecs, statistics.
//!
//! The paper clusters USGS EarthExplorer aerial orthoimages; those are
//! proprietary-ish downloads we cannot ship, so [`SyntheticOrtho`]
//! generates statistically similar multi-band scenes at the paper's exact
//! pixel dimensions (DESIGN.md §5 documents the substitution). [`Raster`]
//! is the in-memory representation every other module works on; [`ppm`]
//! writes portable pixmaps so the Figures 3–7 analogues can be eyeballed.

pub mod ops;
mod ppm;
mod raster;
mod source;
mod synthetic;

pub use ppm::{ppm_dims, read_ppm, write_labels_pgm, write_labels_ppm, write_ppm, PpmHeader, PALETTE};
pub use raster::{Raster, RasterStats};
pub use source::{collect_source, PpmSource, RasterCursor, RasterSource, SyntheticSource};
pub use synthetic::{SyntheticOrtho, SyntheticStream};
