//! Streaming raster sources: header + sequential strip decode.
//!
//! The paper's premise is imagery that does *not* comfortably fit the
//! machine ("size more than 1000x1000 in a legacy system"), yet the
//! seed pipeline materialized every image as a full in-memory
//! [`Raster`] before the strip store copied it again — peak memory ≥2×
//! the image and unbounded in image height. A [`RasterSource`] is the
//! fix: it exposes the geometry up front (the header) and then decodes
//! the image **once, top to bottom, one strip at a time**, never
//! holding more than one strip. [`crate::stripstore::StripStore::ingest`]
//! builds a store from any source; with file backing the peak resident
//! pixel footprint of ingestion is a single strip regardless of image
//! height.
//!
//! Three implementations cover every entry point:
//!
//! - [`PpmSource`] — streaming binary-P6 decoder over the shared header
//!   parser (the one behind [`super::ppm_dims`] and [`super::read_ppm`]);
//!   holds one strip of bytes at a time;
//! - [`RasterCursor`] — adapts an already-resident [`Raster`]
//!   (back-compat: the in-memory paths ingest through the same code);
//! - [`SyntheticSource`] — generates strips on demand from a
//!   [`SyntheticOrtho`] row stream, bit-identical to
//!   [`SyntheticOrtho::generate`].

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::ppm::{read_header, PpmHeader};
use super::raster::Raster;
use super::synthetic::{SyntheticOrtho, SyntheticStream};

/// A raster that can be decoded sequentially, strip by strip. The
/// contract is strictly forward: callers pull strips in order from row
/// 0 and a source is exhausted after `height` rows. Implementations
/// must be deterministic — two ingestions of the same source description
/// yield identical samples (the root of streamed-vs-in-memory
/// bit-identity).
pub trait RasterSource: Send {
    fn height(&self) -> usize;
    fn width(&self) -> usize;
    fn channels(&self) -> usize;

    /// Decode the next ≤ `max_rows` rows as interleaved f32 samples
    /// appended to `out` (cleared first). Returns the row count
    /// produced; 0 means the source is exhausted.
    fn next_strip(&mut self, max_rows: usize, out: &mut Vec<f32>) -> Result<usize>;

    /// Total pixel count (not samples).
    fn pixels(&self) -> usize {
        self.height() * self.width()
    }
}

/// Streaming binary-PPM decoder: the header is parsed at open (shared
/// parser — see [`super::ppm_dims`]); pixel rows are decoded on demand,
/// u8 → f32 exactly as [`super::read_ppm`] promotes them.
pub struct PpmSource {
    header: PpmHeader,
    reader: BufReader<File>,
    next_row: usize,
    byte_buf: Vec<u8>,
}

impl PpmSource {
    pub fn open(path: &Path) -> Result<PpmSource> {
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut reader = BufReader::new(f);
        let header = read_header(&mut reader)
            .with_context(|| format!("parse header of {}", path.display()))?;
        Ok(PpmSource {
            header,
            reader,
            next_row: 0,
            byte_buf: Vec::new(),
        })
    }

    pub fn header(&self) -> &PpmHeader {
        &self.header
    }
}

impl RasterSource for PpmSource {
    fn height(&self) -> usize {
        self.header.height
    }

    fn width(&self) -> usize {
        self.header.width
    }

    fn channels(&self) -> usize {
        self.header.channels()
    }

    fn next_strip(&mut self, max_rows: usize, out: &mut Vec<f32>) -> Result<usize> {
        out.clear();
        let rows = max_rows.min(self.header.height - self.next_row);
        if rows == 0 {
            return Ok(0);
        }
        let bytes = rows * self.header.row_bytes();
        self.byte_buf.resize(bytes, 0);
        self.reader
            .read_exact(&mut self.byte_buf)
            .with_context(|| format!("pixel payload rows {}..{}", self.next_row, self.next_row + rows))?;
        out.extend(self.byte_buf.iter().map(|&b| b as f32));
        self.next_row += rows;
        Ok(rows)
    }
}

/// Sequential cursor over an in-memory raster — the back-compat
/// adapter, so [`crate::stripstore::StripStore::new`] and the streaming
/// ingest share one write path (identical strip layout by construction).
pub struct RasterCursor {
    img: Arc<Raster>,
    next_row: usize,
}

impl RasterCursor {
    pub fn new(img: Arc<Raster>) -> RasterCursor {
        RasterCursor { img, next_row: 0 }
    }
}

impl RasterSource for RasterCursor {
    fn height(&self) -> usize {
        self.img.height()
    }

    fn width(&self) -> usize {
        self.img.width()
    }

    fn channels(&self) -> usize {
        self.img.channels()
    }

    fn next_strip(&mut self, max_rows: usize, out: &mut Vec<f32>) -> Result<usize> {
        out.clear();
        let rows = max_rows.min(self.img.height() - self.next_row);
        if rows == 0 {
            return Ok(0);
        }
        let samples_per_row = self.img.width() * self.img.channels();
        let start = self.next_row * samples_per_row;
        out.extend_from_slice(&self.img.data()[start..start + rows * samples_per_row]);
        self.next_row += rows;
        Ok(rows)
    }
}

/// Strip-on-demand synthetic orthoimagery: wraps a [`SyntheticStream`],
/// so a 4096-row scene can be ingested under a strip-sized budget while
/// producing exactly the pixels [`SyntheticOrtho::generate`] would.
pub struct SyntheticSource {
    stream: SyntheticStream,
}

impl SyntheticSource {
    pub fn new(gen: &SyntheticOrtho, height: usize, width: usize) -> SyntheticSource {
        SyntheticSource {
            stream: gen.stream(height, width),
        }
    }
}

impl RasterSource for SyntheticSource {
    fn height(&self) -> usize {
        self.stream.height()
    }

    fn width(&self) -> usize {
        self.stream.width()
    }

    fn channels(&self) -> usize {
        self.stream.channels()
    }

    fn next_strip(&mut self, max_rows: usize, out: &mut Vec<f32>) -> Result<usize> {
        out.clear();
        ensure!(max_rows > 0, "next_strip needs a positive row budget");
        Ok(self.stream.next_rows(max_rows, out, None))
    }
}

/// Drain a source fully into a [`Raster`] (tests and small inputs —
/// this is the one helper that deliberately holds the whole image).
pub fn collect_source(src: &mut dyn RasterSource) -> Result<Raster> {
    let (h, w, c) = (src.height(), src.width(), src.channels());
    let mut data = Vec::with_capacity(h * w * c);
    let mut strip = Vec::new();
    loop {
        let rows = src.next_strip(h.max(1), &mut strip)?;
        if rows == 0 {
            break;
        }
        data.extend_from_slice(&strip);
    }
    ensure!(
        data.len() == h * w * c,
        "source produced {} samples, want {}x{}x{}",
        data.len(),
        h,
        w,
        c
    );
    Ok(Raster::from_vec(h, w, c, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{write_ppm, SyntheticOrtho};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("blockms_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn ppm_source_streams_what_read_ppm_loads() {
        let img = SyntheticOrtho::default().with_seed(31).generate(23, 17);
        let path = tmp("stream.ppm");
        write_ppm(&img, &path).unwrap();
        let whole = crate::image::read_ppm(&path).unwrap();
        for strip in [1usize, 5, 23, 64] {
            let mut src = PpmSource::open(&path).unwrap();
            assert_eq!((src.height(), src.width(), src.channels()), (23, 17, 3));
            let mut got = Vec::new();
            let mut buf = Vec::new();
            while src.next_strip(strip, &mut buf).unwrap() > 0 {
                got.extend_from_slice(&buf);
            }
            assert_eq!(got, whole.data(), "strip={strip}");
            assert_eq!(src.next_strip(strip, &mut buf).unwrap(), 0, "exhausted");
        }
    }

    #[test]
    fn ppm_source_truncated_payload_errors() {
        let img = SyntheticOrtho::default().with_seed(32).generate(8, 8);
        let path = tmp("short.ppm");
        write_ppm(&img, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        let mut src = PpmSource::open(&path).unwrap();
        let mut buf = Vec::new();
        let mut err = None;
        for _ in 0..8 {
            if let Err(e) = src.next_strip(2, &mut buf) {
                err = Some(e);
                break;
            }
        }
        assert!(err.is_some(), "truncated payload must surface");
    }

    #[test]
    fn raster_cursor_round_trips() {
        let img = Arc::new(SyntheticOrtho::default().with_seed(33).generate(11, 6));
        let mut src = RasterCursor::new(Arc::clone(&img));
        let back = collect_source(&mut src).unwrap();
        assert_eq!(&back, img.as_ref());
    }

    #[test]
    fn synthetic_source_matches_generate() {
        let gen = SyntheticOrtho::default().with_seed(34);
        let img = gen.generate(19, 13);
        let mut src = SyntheticSource::new(&gen, 19, 13);
        let mut got = Vec::new();
        let mut buf = Vec::new();
        while src.next_strip(4, &mut buf).unwrap() > 0 {
            got.extend_from_slice(&buf);
        }
        assert_eq!(got, img.data());
    }
}
