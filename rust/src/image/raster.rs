//! In-memory raster: row-major, channel-interleaved `f32` samples.
//!
//! Layout matches what the AOT kernels consume — a block crop flattens
//! directly into the `pixels[P, C]` chunk layout with zero reshuffling
//! (`P = rows×cols` in row-major order, `C` interleaved) — so the hot
//! path is a straight `memcpy` per block row.

use crate::blocks::BlockRegion;

/// A height×width×channels raster of `f32` samples (digital numbers;
/// the paper's 8/16-bit imagery is promoted to f32 on load).
#[derive(Clone, Debug, PartialEq)]
pub struct Raster {
    height: usize,
    width: usize,
    channels: usize,
    data: Vec<f32>,
}

/// Per-band summary statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct RasterStats {
    pub min: Vec<f32>,
    pub max: Vec<f32>,
    pub mean: Vec<f64>,
}

impl Raster {
    /// Allocate a zero-filled raster.
    pub fn zeros(height: usize, width: usize, channels: usize) -> Raster {
        assert!(height > 0 && width > 0 && channels > 0, "degenerate raster");
        Raster {
            height,
            width,
            channels,
            data: vec![0.0; height * width * channels],
        }
    }

    /// Wrap an existing buffer (must be `height*width*channels` long).
    pub fn from_vec(height: usize, width: usize, channels: usize, data: Vec<f32>) -> Raster {
        assert_eq!(
            data.len(),
            height * width * channels,
            "buffer length {} != {}x{}x{}",
            data.len(),
            height,
            width,
            channels
        );
        Raster {
            height,
            width,
            channels,
            data,
        }
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Total pixel count (not samples).
    pub fn pixels(&self) -> usize {
        self.height * self.width
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.height && col < self.width);
        (row * self.width + col) * self.channels
    }

    /// One pixel's samples.
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> &[f32] {
        let i = self.idx(row, col);
        &self.data[i..i + self.channels]
    }

    #[inline]
    pub fn set(&mut self, row: usize, col: usize, px: &[f32]) {
        assert_eq!(px.len(), self.channels);
        let i = self.idx(row, col);
        self.data[i..i + self.channels].copy_from_slice(px);
    }

    /// One full row of interleaved samples.
    #[inline]
    pub fn row(&self, row: usize) -> &[f32] {
        let i = self.idx(row, 0);
        &self.data[i..i + self.width * self.channels]
    }

    /// Copy a rectangular region into a flat `pixels[P, C]` buffer
    /// (row-major within the region) — the exact layout the kernels and
    /// the sequential baseline consume.
    pub fn crop_into(&self, region: &BlockRegion, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(region.rows() * region.cols() * self.channels);
        for r in region.row0..region.row0 + region.rows() {
            let start = self.idx(r, region.col0);
            out.extend_from_slice(&self.data[start..start + region.cols() * self.channels]);
        }
    }

    /// Convenience: crop to a fresh vector.
    pub fn crop(&self, region: &BlockRegion) -> Vec<f32> {
        let mut v = Vec::new();
        self.crop_into(region, &mut v);
        v
    }

    /// Flatten the whole image as a `pixels[P, C]` slice view.
    pub fn as_pixels(&self) -> &[f32] {
        &self.data
    }

    /// Per-band statistics.
    pub fn stats(&self) -> RasterStats {
        let c = self.channels;
        let mut min = vec![f32::INFINITY; c];
        let mut max = vec![f32::NEG_INFINITY; c];
        let mut sum = vec![0.0f64; c];
        for px in self.data.chunks_exact(c) {
            for (b, &v) in px.iter().enumerate() {
                if v < min[b] {
                    min[b] = v;
                }
                if v > max[b] {
                    max[b] = v;
                }
                sum[b] += v as f64;
            }
        }
        let n = self.pixels() as f64;
        RasterStats {
            min,
            max,
            mean: sum.iter().map(|s| s / n).collect(),
        }
    }

    /// Byte size of the sample buffer (for the I/O cost model).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockRegion;

    fn ramp(h: usize, w: usize, c: usize) -> Raster {
        let mut r = Raster::zeros(h, w, c);
        for row in 0..h {
            for col in 0..w {
                let px: Vec<f32> = (0..c).map(|b| (row * w + col) as f32 + b as f32 * 0.1).collect();
                r.set(row, col, &px);
            }
        }
        r
    }

    #[test]
    fn get_set_round_trip() {
        let mut r = Raster::zeros(4, 5, 3);
        r.set(2, 3, &[1.0, 2.0, 3.0]);
        assert_eq!(r.get(2, 3), &[1.0, 2.0, 3.0]);
        assert_eq!(r.get(0, 0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn row_slice_is_contiguous() {
        let r = ramp(3, 4, 2);
        let row1 = r.row(1);
        assert_eq!(row1.len(), 4 * 2);
        assert_eq!(row1[0], r.get(1, 0)[0]);
        assert_eq!(row1[7], r.get(1, 3)[1]);
    }

    #[test]
    fn crop_matches_manual_copy() {
        let r = ramp(6, 7, 3);
        let region = BlockRegion::new(1, 2, 3, 4);
        let c = r.crop(&region);
        assert_eq!(c.len(), 3 * 4 * 3);
        let mut want = Vec::new();
        for row in 1..4 {
            for col in 2..6 {
                want.extend_from_slice(r.get(row, col));
            }
        }
        assert_eq!(c, want);
    }

    #[test]
    fn crop_full_image_equals_data() {
        let r = ramp(5, 5, 3);
        let full = BlockRegion::new(0, 0, 5, 5);
        assert_eq!(r.crop(&full), r.data().to_vec());
    }

    #[test]
    fn stats_ramp() {
        let r = ramp(2, 2, 1);
        let s = r.stats();
        assert_eq!(s.min[0], 0.0);
        assert_eq!(s.max[0], 3.0);
        assert!((s.mean[0] - 1.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "buffer length")]
    fn from_vec_checks_len() {
        Raster::from_vec(2, 2, 3, vec![0.0; 5]);
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn zero_dims_rejected() {
        Raster::zeros(0, 4, 3);
    }
}
