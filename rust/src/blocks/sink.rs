//! Spillable label output: dense in memory below budget, strip-spooled
//! to disk above it.
//!
//! The seed pipeline's final artifact was always a dense `Vec<u32>` of
//! `height × width` labels — 4 bytes per pixel that, for the tall
//! orthoimagery the paper targets, can rival the image itself. A
//! [`LabelSink`] keeps the familiar dense assembler when the map fits
//! the budget and otherwise spools block placements into a temporary
//! file of little-endian u32s ([`SpillAssembler`]), with the same
//! bounds/duplicate/overlap/coverage discipline (one shared
//! coverage tracker with [`LabelAssembler`]). The finished [`LabelMap`] serves
//! both shapes: whole-map access for the in-memory case, bounded
//! strip-at-a-time iteration plus streaming PGM/PPM export for the
//! spooled case — labels go from disk back to disk without a full map
//! ever being resident.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use super::assemble::{Coverage, LabelAssembler};
use super::region::BlockRegion;
use crate::image::PALETTE;

/// Process-wide sequence so concurrent spools (pid collisions across
/// processes included) never share a file.
static SPOOL_SEQ: AtomicU64 = AtomicU64::new(0);

fn spool_path(height: usize, width: usize) -> PathBuf {
    let seq = SPOOL_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "blockms_labels_p{}_{seq}_{height}x{width}.u32le",
        std::process::id()
    ))
}

/// Where assembled labels accumulate: dense memory below the budget, a
/// spool file above it.
#[derive(Debug)]
pub enum LabelSink {
    Memory(LabelAssembler),
    Spill(SpillAssembler),
}

impl LabelSink {
    /// Dense when `height × width × 4` fits `budget_bytes` (or no
    /// budget is given — the seed behaviour); spooled otherwise.
    pub fn new(height: usize, width: usize, budget_bytes: Option<u64>) -> Result<LabelSink> {
        let dense_bytes = (height * width * 4) as u64;
        match budget_bytes {
            Some(budget) if dense_bytes > budget => {
                Ok(LabelSink::Spill(SpillAssembler::create(height, width)?))
            }
            _ => Ok(LabelSink::Memory(LabelAssembler::new(height, width))),
        }
    }

    /// Is this sink spooling to disk?
    pub fn spills(&self) -> bool {
        matches!(self, LabelSink::Spill(_))
    }

    /// Pixels placed so far — the spooled-label **cursor** the
    /// checkpoint format records. Assembly is exactly-once (overlaps
    /// and duplicates are rejected at claim time), so this count fully
    /// describes assembly progress at a round boundary: the assign
    /// round places every block exactly once, and a checkpoint is only
    /// taken between rounds, where the cursor is 0 (global mode spools
    /// labels only in the final assign round).
    pub fn cursor(&self) -> u64 {
        match self {
            LabelSink::Memory(asm) => asm.written() as u64,
            LabelSink::Spill(sp) => sp.written() as u64,
        }
    }

    /// Place one block's labels (row-major within the region); same
    /// contract as [`LabelAssembler::place`] on both variants.
    pub fn place(&mut self, region: &BlockRegion, labels: &[u32]) -> Result<()> {
        match self {
            LabelSink::Memory(asm) => asm.place(region, labels).map_err(anyhow::Error::new),
            LabelSink::Spill(sp) => sp.place(region, labels),
        }
    }

    /// Finish: every pixel written exactly once.
    pub fn finish(self) -> Result<LabelMap> {
        match self {
            LabelSink::Memory(asm) => Ok(LabelMap::Dense(asm.finish()?)),
            LabelSink::Spill(sp) => Ok(LabelMap::Spooled(sp.finish()?)),
        }
    }
}

/// File-backed assembler: a preallocated `height × width` u32-LE map,
/// written by `seek + write` per block row. Resident memory is one
/// block row's worth of bytes, independent of image height.
#[derive(Debug)]
pub struct SpillAssembler {
    height: usize,
    width: usize,
    path: PathBuf,
    /// `Some` while assembling; taken by `finish` (drop with `Some`
    /// means an abandoned assembly, whose file is removed).
    file: Option<File>,
    coverage: Coverage,
    row_bytes: Vec<u8>,
}

impl SpillAssembler {
    fn create(height: usize, width: usize) -> Result<SpillAssembler> {
        let path = spool_path(height, width);
        let file = File::options()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("create label spool {}", path.display()))?;
        file.set_len((height * width * 4) as u64)
            .context("size label spool")?;
        Ok(SpillAssembler {
            height,
            width,
            path,
            file: Some(file),
            coverage: Coverage::new(height, width),
            row_bytes: Vec::new(),
        })
    }

    pub fn place(&mut self, region: &BlockRegion, labels: &[u32]) -> Result<()> {
        self.coverage.claim(region, labels.len())?;
        let file = self.file.as_mut().expect("spool open while assembling");
        for (ri, r) in (region.row0..region.row_end()).enumerate() {
            let src = &labels[ri * region.cols()..(ri + 1) * region.cols()];
            self.row_bytes.clear();
            self.row_bytes
                .extend(src.iter().flat_map(|l| l.to_le_bytes()));
            let offset = ((r * self.width + region.col0) * 4) as u64;
            file.seek(SeekFrom::Start(offset))
                .context("seek label spool")?;
            file.write_all(&self.row_bytes)
                .context("write label spool")?;
        }
        Ok(())
    }

    /// Pixels written so far.
    pub fn written(&self) -> usize {
        self.coverage.written()
    }

    fn finish(mut self) -> Result<LabelSpool> {
        self.coverage.finish_check()?;
        let mut file = self.file.take().expect("spool open while assembling");
        file.flush().context("flush label spool")?;
        Ok(LabelSpool {
            height: self.height,
            width: self.width,
            path: self.path.clone(),
            file: Mutex::new(file),
        })
    }
}

impl Drop for SpillAssembler {
    fn drop(&mut self) {
        // Only an *abandoned* assembly cleans up; a finished one handed
        // its file (and cleanup duty) to the LabelSpool.
        if self.file.is_some() {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// A finished, disk-resident label map. Reads are strip-granular
/// ([`LabelSpool::read_rows`]); [`LabelMap::into_dense`] exists for
/// tests and small maps. The backing file is deleted on drop.
#[derive(Debug)]
pub struct LabelSpool {
    height: usize,
    width: usize,
    path: PathBuf,
    file: Mutex<File>,
}

impl LabelSpool {
    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read `rows` label rows starting at `row0` into `out`.
    pub fn read_rows(&self, row0: usize, rows: usize, out: &mut Vec<u32>) -> Result<()> {
        ensure!(
            row0 + rows <= self.height,
            "rows {row0}..{} outside {} label rows",
            row0 + rows,
            self.height
        );
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start((row0 * self.width * 4) as u64))
            .context("seek label spool")?;
        let mut bytes = vec![0u8; rows * self.width * 4];
        f.read_exact(&mut bytes).context("read label spool")?;
        out.clear();
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])),
        );
        Ok(())
    }
}

impl Drop for LabelSpool {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// The finished label map, dense or spooled — one type downstream code
/// can hold without caring where the labels live.
#[derive(Debug)]
pub enum LabelMap {
    Dense(Vec<u32>),
    Spooled(LabelSpool),
}

impl LabelMap {
    /// Total label count (`height × width` when complete).
    pub fn len(&self) -> usize {
        match self {
            LabelMap::Dense(v) => v.len(),
            LabelMap::Spooled(s) => s.height * s.width,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Did this map spill to disk?
    pub fn is_spooled(&self) -> bool {
        matches!(self, LabelMap::Spooled(_))
    }

    /// Borrow the dense labels, when resident.
    pub fn as_dense(&self) -> Option<&[u32]> {
        match self {
            LabelMap::Dense(v) => Some(v),
            LabelMap::Spooled(_) => None,
        }
    }

    /// Visit the map in bounded chunks of `chunk_rows` rows:
    /// `f(first_row, row-major labels)`. Resident memory for a spooled
    /// map is one chunk.
    pub fn for_each_rows(
        &self,
        chunk_rows: usize,
        mut f: impl FnMut(usize, &[u32]) -> Result<()>,
    ) -> Result<()> {
        assert!(chunk_rows > 0, "chunk_rows must be positive");
        match self {
            LabelMap::Dense(_) => {
                // Dense maps don't know their geometry; serve one chunk.
                let v = self.as_dense().expect("dense");
                f(0, v)
            }
            LabelMap::Spooled(s) => {
                let mut buf = Vec::new();
                let mut row = 0;
                while row < s.height {
                    let rows = chunk_rows.min(s.height - row);
                    s.read_rows(row, rows, &mut buf)?;
                    f(row, &buf)?;
                    row += rows;
                }
                Ok(())
            }
        }
    }

    /// Materialize as a dense vector (spooled maps read back fully —
    /// back-compat and tests; the streaming CLI path uses
    /// [`LabelMap::write_labels_ppm`] instead).
    pub fn into_dense(self) -> Result<Vec<u32>> {
        match self {
            LabelMap::Dense(v) => Ok(v),
            LabelMap::Spooled(s) => {
                let mut all = Vec::with_capacity(s.height * s.width);
                let mut buf = Vec::new();
                let mut row = 0;
                while row < s.height {
                    let rows = 64usize.min(s.height - row);
                    s.read_rows(row, rows, &mut buf)?;
                    all.extend_from_slice(&buf);
                    row += rows;
                }
                Ok(all)
            }
        }
    }

    /// Write the map as a palette-colored binary PPM, strip by strip —
    /// a spooled map streams disk → disk under a bounded buffer.
    pub fn write_labels_ppm(&self, height: usize, width: usize, path: &Path) -> Result<()> {
        ensure!(
            self.len() == height * width,
            "label map {} != {height}x{width}",
            self.len()
        );
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "P6\n{width} {height}\n255")?;
        let mut row_buf = Vec::with_capacity(width * 3);
        self.for_each_rows(64, |_, labels| {
            for row in labels.chunks(width) {
                row_buf.clear();
                for &l in row {
                    row_buf.extend_from_slice(&PALETTE[l as usize % PALETTE.len()]);
                }
                w.write_all(&row_buf)?;
            }
            Ok(())
        })?;
        w.flush()?;
        Ok(())
    }

    /// Write the map as a grayscale PGM (`label * 255 / (k-1)`), strip
    /// by strip.
    pub fn write_labels_pgm(
        &self,
        height: usize,
        width: usize,
        k: usize,
        path: &Path,
    ) -> Result<()> {
        ensure!(
            self.len() == height * width,
            "label map {} != {height}x{width}",
            self.len()
        );
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "P5\n{width} {height}\n255")?;
        let scale = if k > 1 { 255 / (k - 1) as u32 } else { 255 };
        let mut row_buf = Vec::with_capacity(width);
        self.for_each_rows(64, |_, labels| {
            for row in labels.chunks(width) {
                row_buf.clear();
                row_buf.extend(row.iter().map(|&l| (l * scale).min(255) as u8));
                w.write_all(&row_buf)?;
            }
            Ok(())
        })?;
        w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{AssembleError, BlockPlan, BlockShape};

    fn fill(sink: &mut LabelSink, h: usize, w: usize, side: usize) {
        let plan = BlockPlan::new(h, w, BlockShape::Square { side });
        for region in plan.iter() {
            let mut buf = Vec::with_capacity(region.area());
            for r in region.row0..region.row_end() {
                for c in region.col0..region.col_end() {
                    buf.push((r * w + c) as u32);
                }
            }
            sink.place(region, &buf).unwrap();
        }
    }

    #[test]
    fn memory_sink_round_trips() {
        let mut sink = LabelSink::new(7, 9, None).unwrap();
        assert!(!sink.spills());
        fill(&mut sink, 7, 9, 3);
        let map = sink.finish().unwrap();
        assert!(!map.is_spooled());
        let want: Vec<u32> = (0..63).collect();
        assert_eq!(map.into_dense().unwrap(), want);
    }

    #[test]
    fn spill_sink_round_trips_identically() {
        // Budget of 0 forces the spool; contents must equal the dense
        // assembly exactly.
        let mut sink = LabelSink::new(7, 9, Some(0)).unwrap();
        assert!(sink.spills());
        fill(&mut sink, 7, 9, 3);
        let map = sink.finish().unwrap();
        assert!(map.is_spooled());
        let want: Vec<u32> = (0..63).collect();
        assert_eq!(map.into_dense().unwrap(), want);
    }

    #[test]
    fn cursor_tracks_pixels_placed_on_both_variants() {
        for budget in [None, Some(0)] {
            let mut sink = LabelSink::new(4, 4, budget).unwrap();
            assert_eq!(sink.cursor(), 0);
            sink.place(&BlockRegion::new(0, 0, 2, 2), &[1; 4]).unwrap();
            assert_eq!(sink.cursor(), 4, "budget={budget:?}");
            sink.place(&BlockRegion::new(0, 2, 2, 2), &[2; 4]).unwrap();
            assert_eq!(sink.cursor(), 8);
            // a rejected placement must not advance the cursor
            assert!(sink.place(&BlockRegion::new(0, 0, 2, 2), &[3; 4]).is_err());
            assert_eq!(sink.cursor(), 8, "failed place must not count");
        }
    }

    #[test]
    fn budget_threshold_selects_the_variant() {
        // 4x4 labels = 64 bytes
        assert!(!LabelSink::new(4, 4, Some(64)).unwrap().spills());
        assert!(LabelSink::new(4, 4, Some(63)).unwrap().spills());
        assert!(!LabelSink::new(4, 4, None).unwrap().spills());
    }

    #[test]
    fn spill_rejects_overlap_duplicate_oob_and_incomplete() {
        let mut sink = LabelSink::new(4, 4, Some(0)).unwrap();
        let a = BlockRegion::new(0, 0, 2, 2);
        sink.place(&a, &[1; 4]).unwrap();
        // duplicate
        let err = sink.place(&a, &[1; 4]).unwrap_err();
        assert!(err.downcast_ref::<AssembleError>() == Some(&AssembleError::Duplicate(a)));
        // overlap
        let b = BlockRegion::new(1, 1, 2, 2);
        let err = sink.place(&b, &[2; 4]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<AssembleError>(),
            Some(AssembleError::Overlap(..))
        ));
        // out of bounds
        let err = sink.place(&BlockRegion::new(3, 3, 2, 2), &[0; 4]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<AssembleError>(),
            Some(AssembleError::OutOfBounds(..))
        ));
        // wrong size
        let err = sink.place(&BlockRegion::new(0, 2, 2, 2), &[0; 3]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<AssembleError>(),
            Some(AssembleError::WrongSize(..))
        ));
        // incomplete finish
        let err = sink.finish().unwrap_err();
        assert!(matches!(
            err.downcast_ref::<AssembleError>(),
            Some(AssembleError::Incomplete { written: 4, total: 16 })
        ));
    }

    #[test]
    fn spool_reads_are_strip_granular_and_file_is_cleaned_up() {
        let mut sink = LabelSink::new(6, 4, Some(0)).unwrap();
        fill(&mut sink, 6, 4, 2);
        let map = sink.finish().unwrap();
        let LabelMap::Spooled(ref spool) = map else {
            panic!("expected spool");
        };
        let path = spool.path().to_path_buf();
        assert!(path.exists());
        let mut rows = Vec::new();
        spool.read_rows(2, 2, &mut rows).unwrap();
        assert_eq!(rows, (8..16).collect::<Vec<u32>>());
        assert!(spool.read_rows(5, 2, &mut rows).is_err(), "past the end");
        let mut chunks = Vec::new();
        map.for_each_rows(4, |row0, labels| {
            chunks.push((row0, labels.len()));
            Ok(())
        })
        .unwrap();
        assert_eq!(chunks, vec![(0, 16), (4, 8)]);
        drop(map);
        assert!(!path.exists(), "spool file must be removed on drop");
    }

    #[test]
    fn streamed_ppm_export_matches_dense_writer() {
        let h = 5;
        let w = 6;
        let labels: Vec<u32> = (0..h * w).map(|i| (i % 4) as u32).collect();
        let dir = std::env::temp_dir().join("blockms_sink_tests");
        std::fs::create_dir_all(&dir).unwrap();

        // dense writer (the seed path)
        let dense_path = dir.join("dense.ppm");
        crate::image::write_labels_ppm(&labels, h, w, &dense_path).unwrap();

        // spooled map, streamed export
        let mut sink = LabelSink::new(h, w, Some(0)).unwrap();
        sink.place(&BlockRegion::new(0, 0, h, w), &labels).unwrap();
        let map = sink.finish().unwrap();
        let spool_path = dir.join("spooled.ppm");
        map.write_labels_ppm(h, w, &spool_path).unwrap();
        assert_eq!(
            std::fs::read(&dense_path).unwrap(),
            std::fs::read(&spool_path).unwrap()
        );

        // and the PGM twin
        let pgm_a = dir.join("dense.pgm");
        crate::image::write_labels_pgm(&labels, h, w, 4, &pgm_a).unwrap();
        let pgm_b = dir.join("spooled.pgm");
        map.write_labels_pgm(h, w, 4, &pgm_b).unwrap();
        assert_eq!(std::fs::read(&pgm_a).unwrap(), std::fs::read(&pgm_b).unwrap());
    }
}
