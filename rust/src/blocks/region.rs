//! A rectangular region of an image (one distinct block).

/// Half-open rectangle `[row0, row0+rows) × [col0, col0+cols)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockRegion {
    pub row0: usize,
    pub col0: usize,
    rows: usize,
    cols: usize,
}

impl BlockRegion {
    pub fn new(row0: usize, col0: usize, rows: usize, cols: usize) -> BlockRegion {
        assert!(rows > 0 && cols > 0, "degenerate block {rows}x{cols}");
        BlockRegion {
            row0,
            col0,
            rows,
            cols,
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Pixel count.
    #[inline]
    pub fn area(&self) -> usize {
        self.rows * self.cols
    }

    /// Exclusive end row.
    #[inline]
    pub fn row_end(&self) -> usize {
        self.row0 + self.rows
    }

    /// Exclusive end column.
    #[inline]
    pub fn col_end(&self) -> usize {
        self.col0 + self.cols
    }

    /// Does this region contain the pixel `(row, col)`?
    #[inline]
    pub fn contains(&self, row: usize, col: usize) -> bool {
        row >= self.row0 && row < self.row_end() && col >= self.col0 && col < self.col_end()
    }

    /// Do two regions share any pixel?
    pub fn intersects(&self, other: &BlockRegion) -> bool {
        self.row0 < other.row_end()
            && other.row0 < self.row_end()
            && self.col0 < other.col_end()
            && other.col0 < self.col_end()
    }
}

impl std::fmt::Display for BlockRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[{}..{}, {}..{}]",
            self.row0,
            self.row_end(),
            self.col0,
            self.col_end()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let r = BlockRegion::new(2, 3, 4, 5);
        assert_eq!(r.rows(), 4);
        assert_eq!(r.cols(), 5);
        assert_eq!(r.area(), 20);
        assert_eq!(r.row_end(), 6);
        assert_eq!(r.col_end(), 8);
    }

    #[test]
    fn contains_boundaries() {
        let r = BlockRegion::new(2, 3, 4, 5);
        assert!(r.contains(2, 3));
        assert!(r.contains(5, 7));
        assert!(!r.contains(6, 3));
        assert!(!r.contains(2, 8));
        assert!(!r.contains(1, 3));
    }

    #[test]
    fn intersection() {
        let a = BlockRegion::new(0, 0, 4, 4);
        let b = BlockRegion::new(3, 3, 4, 4);
        let c = BlockRegion::new(4, 0, 2, 2);
        assert!(a.intersects(&b));
        assert!(b.intersects(&a));
        assert!(!a.intersects(&c));
        assert!(!c.intersects(&a));
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn zero_size_rejected() {
        BlockRegion::new(0, 0, 0, 5);
    }
}
