//! Block geometry — the paper's three approaches plus an escape hatch.

use std::fmt;
use std::str::FromStr;

/// The block-partition approach. The paper's three shapes resolve to a
/// concrete `[rows cols]` block size against a given image:
///
/// - [`BlockShape::Rows`] — row-shaped `[band_rows, image_width]`;
/// - [`BlockShape::Cols`] — column-shaped `[image_height, band_cols]`;
/// - [`BlockShape::Square`] — `[side, side]`;
/// - [`BlockShape::Custom`] — any fixed `[rows, cols]` (used to replicate
///   the paper's exact `[1200 4656]` etc. on arbitrary images).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockShape {
    /// Full-width horizontal strips of `band_rows` rows.
    Rows { band_rows: usize },
    /// Full-height vertical strips of `band_cols` columns.
    Cols { band_cols: usize },
    /// Square tiles of `side × side`.
    Square { side: usize },
    /// Fixed `[rows, cols]` tiles.
    Custom { rows: usize, cols: usize },
}

impl BlockShape {
    /// Resolve to the concrete `[rows, cols]` block size for an image.
    /// Block dims are clamped to the image dims (a `[1200 4656]` request
    /// on an 800×600 image yields `[800 600]`-bounded blocks, like
    /// `blockproc`).
    pub fn block_dims(&self, height: usize, width: usize) -> (usize, usize) {
        let (r, c) = match *self {
            BlockShape::Rows { band_rows } => (band_rows, width),
            BlockShape::Cols { band_cols } => (height, band_cols),
            BlockShape::Square { side } => (side, side),
            BlockShape::Custom { rows, cols } => (rows, cols),
        };
        (r.max(1).min(height), c.max(1).min(width))
    }

    /// The paper's label for this approach.
    pub fn label(&self) -> &'static str {
        match self {
            BlockShape::Rows { .. } => "Row-Shaped",
            BlockShape::Cols { .. } => "Column-Shaped",
            BlockShape::Square { .. } => "Square Block",
            BlockShape::Custom { .. } => "Custom",
        }
    }

    /// The paper's canonical parameterization of each approach for a
    /// given image: ~5 pixel-equal blocks per pass (the paper's Cases 1–3
    /// use "approximately the same" pixels per block and ~4–5 blocks on
    /// its 4656×5793 exemplar): row bands of ⌈h/5⌉, column bands of
    /// ⌈w/5⌉, squares of side ⌈sqrt(h·w/5)⌉.
    pub fn paper_default(kind: ApproachKind, height: usize, width: usize) -> BlockShape {
        const TARGET_BLOCKS: f64 = 5.0;
        match kind {
            ApproachKind::Rows => BlockShape::Rows {
                band_rows: (height as f64 / TARGET_BLOCKS).ceil().max(1.0) as usize,
            },
            ApproachKind::Cols => BlockShape::Cols {
                band_cols: (width as f64 / TARGET_BLOCKS).ceil().max(1.0) as usize,
            },
            ApproachKind::Square => {
                let side = (height as f64 * width as f64 / TARGET_BLOCKS).sqrt().ceil();
                BlockShape::Square {
                    side: side.max(1.0) as usize,
                }
            }
        }
    }
}

/// Just the approach *kind*, without a size (what sweeps iterate over).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ApproachKind {
    Rows,
    Cols,
    Square,
}

impl ApproachKind {
    pub const ALL: [ApproachKind; 3] = [ApproachKind::Rows, ApproachKind::Cols, ApproachKind::Square];

    pub fn label(&self) -> &'static str {
        match self {
            ApproachKind::Rows => "Row-Shaped",
            ApproachKind::Cols => "Column-Shaped",
            ApproachKind::Square => "Square Block",
        }
    }
}

impl FromStr for ApproachKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "row" | "rows" | "row-shaped" => Ok(ApproachKind::Rows),
            "col" | "cols" | "column" | "column-shaped" => Ok(ApproachKind::Cols),
            "square" | "sq" | "square-block" => Ok(ApproachKind::Square),
            other => Err(format!(
                "unknown approach {other:?} (want row|column|square)"
            )),
        }
    }
}

impl fmt::Display for BlockShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            BlockShape::Rows { band_rows } => write!(f, "rows[{band_rows} W]"),
            BlockShape::Cols { band_cols } => write!(f, "cols[H {band_cols}]"),
            BlockShape::Square { side } => write!(f, "square[{side} {side}]"),
            BlockShape::Custom { rows, cols } => write!(f, "custom[{rows} {cols}]"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_resolve_against_image() {
        assert_eq!(
            BlockShape::Rows { band_rows: 1200 }.block_dims(5793, 4656),
            (1200, 4656)
        );
        assert_eq!(
            BlockShape::Cols { band_cols: 1000 }.block_dims(5793, 4656),
            (5793, 1000)
        );
        assert_eq!(
            BlockShape::Square { side: 1200 }.block_dims(5793, 4656),
            (1200, 1200)
        );
        assert_eq!(
            BlockShape::Custom { rows: 10, cols: 20 }.block_dims(5793, 4656),
            (10, 20)
        );
    }

    #[test]
    fn dims_clamped_to_image() {
        assert_eq!(
            BlockShape::Square { side: 1200 }.block_dims(800, 600),
            (800, 600)
        );
        assert_eq!(BlockShape::Rows { band_rows: 0 }.block_dims(10, 10), (1, 10));
    }

    #[test]
    fn paper_defaults_have_similar_block_counts_and_areas() {
        // The paper's Cases 1-3 use roughly pixel-equal blocks; our
        // defaults must keep both the block counts and the full-block
        // pixel areas of the three approaches within 2x of each other.
        let (h, w) = (5793, 4656);
        let mut counts = Vec::new();
        let mut areas = Vec::new();
        for kind in ApproachKind::ALL {
            let (br, bc) = BlockShape::paper_default(kind, h, w).block_dims(h, w);
            counts.push((h.div_ceil(br)) * (w.div_ceil(bc)));
            areas.push(br * bc);
        }
        for v in [&counts, &areas] {
            let max = *v.iter().max().unwrap() as f64;
            let min = *v.iter().min().unwrap() as f64;
            assert!(max / min <= 2.0, "diverged: counts {counts:?} areas {areas:?}");
        }
    }

    #[test]
    fn approach_parses() {
        assert_eq!("row".parse::<ApproachKind>().unwrap(), ApproachKind::Rows);
        assert_eq!(
            "Column-Shaped".parse::<ApproachKind>().unwrap(),
            ApproachKind::Cols
        );
        assert_eq!("sq".parse::<ApproachKind>().unwrap(), ApproachKind::Square);
        assert!("diagonal".parse::<ApproachKind>().is_err());
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(ApproachKind::Rows.label(), "Row-Shaped");
        assert_eq!(ApproachKind::Cols.label(), "Column-Shaped");
        assert_eq!(ApproachKind::Square.label(), "Square Block");
    }
}
