//! Materialized block plans: an exact tiling of the image by regions.

use super::region::BlockRegion;
use super::shape::BlockShape;

/// A deterministic, gap-free, overlap-free tiling of a `height×width`
/// image into distinct blocks, in row-major block order (the order
/// `blockproc` visits blocks, and the order the scheduler enqueues them).
#[derive(Clone, Debug, PartialEq)]
pub struct BlockPlan {
    height: usize,
    width: usize,
    shape: BlockShape,
    block_rows: usize,
    block_cols: usize,
    regions: Vec<BlockRegion>,
}

impl BlockPlan {
    /// Build the plan for `shape` over a `height×width` image.
    pub fn new(height: usize, width: usize, shape: BlockShape) -> BlockPlan {
        assert!(height > 0 && width > 0, "degenerate image {height}x{width}");
        let (br, bc) = shape.block_dims(height, width);
        let grid_rows = height.div_ceil(br);
        let grid_cols = width.div_ceil(bc);
        let mut regions = Vec::with_capacity(grid_rows * grid_cols);
        for gr in 0..grid_rows {
            let row0 = gr * br;
            let rows = br.min(height - row0);
            for gc in 0..grid_cols {
                let col0 = gc * bc;
                let cols = bc.min(width - col0);
                regions.push(BlockRegion::new(row0, col0, rows, cols));
            }
        }
        BlockPlan {
            height,
            width,
            shape,
            block_rows: br,
            block_cols: bc,
            regions,
        }
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn shape(&self) -> BlockShape {
        self.shape
    }

    /// Resolved full-block dims `[rows, cols]`.
    pub fn block_dims(&self) -> (usize, usize) {
        (self.block_rows, self.block_cols)
    }

    /// Grid extent in blocks `(grid_rows, grid_cols)`.
    pub fn grid_dims(&self) -> (usize, usize) {
        (
            self.height.div_ceil(self.block_rows),
            self.width.div_ceil(self.block_cols),
        )
    }

    pub fn len(&self) -> usize {
        self.regions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }

    pub fn regions(&self) -> &[BlockRegion] {
        &self.regions
    }

    pub fn region(&self, i: usize) -> &BlockRegion {
        &self.regions[i]
    }

    pub fn iter(&self) -> impl Iterator<Item = &BlockRegion> {
        self.regions.iter()
    }

    /// Largest block pixel count (what the chunker sizes buffers for).
    pub fn max_block_area(&self) -> usize {
        self.regions.iter().map(BlockRegion::area).max().unwrap_or(0)
    }

    /// Sum of block areas — must equal `height*width` (tested invariant).
    pub fn total_area(&self) -> usize {
        self.regions.iter().map(BlockRegion::area).sum()
    }

    /// Which block contains pixel `(row, col)`.
    pub fn block_of(&self, row: usize, col: usize) -> usize {
        assert!(row < self.height && col < self.width, "pixel outside image");
        let (_, grid_cols) = self.grid_dims();
        (row / self.block_rows) * grid_cols + col / self.block_cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_exact_cover(plan: &BlockPlan) {
        // no overlap, total area matches, every pixel found by block_of
        assert_eq!(plan.total_area(), plan.height() * plan.width());
        for (i, a) in plan.regions().iter().enumerate() {
            for b in plan.regions().iter().skip(i + 1) {
                assert!(!a.intersects(b), "{a} overlaps {b}");
            }
        }
        // spot-check block_of on a grid of pixels
        for row in (0..plan.height()).step_by((plan.height() / 13).max(1)) {
            for col in (0..plan.width()).step_by((plan.width() / 13).max(1)) {
                let bi = plan.block_of(row, col);
                assert!(plan.region(bi).contains(row, col));
            }
        }
    }

    #[test]
    fn paper_case_square() {
        // Case 1: [1200 1200] on 4656-wide, 5793-tall image (w x h in the
        // paper's phrasing; ours is h=5793? The paper's image is
        // "4656x5793" = width 4656, height 5793 in its file-layout prose).
        let plan = BlockPlan::new(5793, 4656, BlockShape::Square { side: 1200 });
        let (gr, gc) = plan.grid_dims();
        assert_eq!(gc, 4); // 4656/1200 = 3.88 -> 4 blocks wide
        assert_eq!(gr, 5); // 5793/1200 = 4.83 -> 5 blocks tall
        assert_eq!(plan.len(), 20);
        assert_exact_cover(&plan);
    }

    #[test]
    fn paper_case_rows() {
        // Case 2: [1200 4656] spans the width.
        let plan = BlockPlan::new(
            5793,
            4656,
            BlockShape::Custom {
                rows: 1200,
                cols: 4656,
            },
        );
        let (gr, gc) = plan.grid_dims();
        assert_eq!((gr, gc), (5, 1));
        assert_exact_cover(&plan);
    }

    #[test]
    fn paper_case_cols() {
        // Case 3: [5793 1000] spans the height; 4.656 -> 5 blocks wide.
        let plan = BlockPlan::new(
            5793,
            4656,
            BlockShape::Custom {
                rows: 5793,
                cols: 1000,
            },
        );
        let (gr, gc) = plan.grid_dims();
        assert_eq!((gr, gc), (1, 5));
        assert_exact_cover(&plan);
        // last block is partial: 4656 - 4*1000 = 656 cols
        assert_eq!(plan.region(4).cols(), 656);
    }

    #[test]
    fn single_block_when_shape_covers_image() {
        let plan = BlockPlan::new(100, 200, BlockShape::Square { side: 4000 });
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.region(0).rows(), 100);
        assert_eq!(plan.region(0).cols(), 200);
    }

    #[test]
    fn one_pixel_blocks() {
        let plan = BlockPlan::new(3, 4, BlockShape::Square { side: 1 });
        assert_eq!(plan.len(), 12);
        assert_exact_cover(&plan);
    }

    #[test]
    fn row_major_order() {
        let plan = BlockPlan::new(4, 4, BlockShape::Square { side: 2 });
        let r: Vec<(usize, usize)> = plan.iter().map(|b| (b.row0, b.col0)).collect();
        assert_eq!(r, vec![(0, 0), (0, 2), (2, 0), (2, 2)]);
    }

    #[test]
    fn max_block_area() {
        let plan = BlockPlan::new(5, 5, BlockShape::Square { side: 3 });
        assert_eq!(plan.max_block_area(), 9);
        assert_eq!(plan.total_area(), 25);
    }

    #[test]
    #[should_panic(expected = "outside image")]
    fn block_of_out_of_bounds() {
        BlockPlan::new(4, 4, BlockShape::Square { side: 2 }).block_of(4, 0);
    }
}
