//! Distinct-block partitioning — the paper's core abstraction.
//!
//! MATLAB's `blockproc` performs a *distinct block operation*: the image
//! is tiled by non-overlapping blocks of a requested `[rows cols]` size
//! (edge blocks are partial), each block is processed independently, and
//! the results are reassembled. The paper's three approaches are three
//! block geometries on the same image:
//!
//! - **Row-shaped** `[h W]` — full-width strips (paper: `[1200 4656]`);
//! - **Column-shaped** `[H w]` — full-height columns (paper: `[5793 1000]`);
//! - **Square** `[s s]` — tiles (paper: `[1200 1200]`).
//!
//! [`BlockShape`] names the approach, [`BlockPlan`] materializes it into
//! an exact, gap-free, overlap-free cover of the image ([`BlockRegion`]s
//! in deterministic row-major order), and [`assemble`] scatters per-block
//! label results back into the output map.

mod assemble;
mod plan;
mod region;
pub(crate) mod shape;
pub mod sink;
pub mod sliding;

pub use assemble::{AssembleError, LabelAssembler};
pub use plan::BlockPlan;
pub use region::BlockRegion;
pub use shape::{ApproachKind, BlockShape};
pub use sink::{LabelMap, LabelSink, LabelSpool, SpillAssembler};
pub use sliding::{padded_crop, sliding_apply, NeighborhoodOp, PadMethod};
