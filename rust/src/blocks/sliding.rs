//! Sliding-neighborhood operations — the *other* block-processing mode.
//!
//! The paper (§3) names two block-processing operations: distinct blocks
//! (what K-Means uses; [`super::BlockPlan`]) and **sliding neighborhood**
//! — every output pixel is a function of its `win×win` neighborhood.
//! MATLAB parallelizes these the same way: split the image into distinct
//! blocks, *pad each block with a halo* of `win/2` border pixels so
//! neighborhoods never cross a worker boundary, process blocks
//! independently, and reassemble. This module provides that substrate:
//!
//! - [`PadMethod`] — MATLAB `blockproc`-style border semantics
//!   (zeros / replicate / symmetric);
//! - [`padded_crop`] — crop a region *with halo*, materializing border
//!   padding where the halo leaves the image;
//! - [`sliding_apply`] — parallel sliding-neighborhood map over a block
//!   plan (scoped worker threads; the kernel sees a padded tile and
//!   writes the interior), with the key invariant that the result is
//!   **identical for every block plan and worker count** (tested).

use std::sync::Mutex;

use crate::image::Raster;

use super::plan::BlockPlan;
use super::region::BlockRegion;

/// Border padding semantics (MATLAB `blockproc`/`nlfilter` options).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PadMethod {
    /// Pad with zeros.
    Zeros,
    /// Repeat the border pixel (`replicate`).
    Replicate,
    /// Mirror across the border (`symmetric`): `abc|cba`.
    Symmetric,
}

/// Resolve a (possibly out-of-range) signed coordinate to a source index
/// under the pad method. Returns `None` for [`PadMethod::Zeros`] misses.
fn resolve(coord: isize, len: usize, pad: PadMethod) -> Option<usize> {
    if coord >= 0 && (coord as usize) < len {
        return Some(coord as usize);
    }
    match pad {
        PadMethod::Zeros => None,
        PadMethod::Replicate => Some(coord.clamp(0, len as isize - 1) as usize),
        PadMethod::Symmetric => {
            // reflect repeatedly: for coord -1 -> 0, -2 -> 1, len -> len-1…
            let period = 2 * len as isize;
            let mut x = coord.rem_euclid(period);
            if x >= len as isize {
                x = period - 1 - x;
            }
            Some(x as usize)
        }
    }
}

/// Crop `region` expanded by `halo` pixels on every side, materializing
/// padding outside the image. Output is `(rows+2h)×(cols+2h)×C`.
pub fn padded_crop(img: &Raster, region: &BlockRegion, halo: usize, pad: PadMethod) -> Vec<f32> {
    let c = img.channels();
    let out_rows = region.rows() + 2 * halo;
    let out_cols = region.cols() + 2 * halo;
    let mut out = vec![0.0f32; out_rows * out_cols * c];
    for orow in 0..out_rows {
        let src_r = resolve(
            region.row0 as isize + orow as isize - halo as isize,
            img.height(),
            pad,
        );
        for ocol in 0..out_cols {
            let src_c = resolve(
                region.col0 as isize + ocol as isize - halo as isize,
                img.width(),
                pad,
            );
            if let (Some(r), Some(col)) = (src_r, src_c) {
                let dst = (orow * out_cols + ocol) * c;
                out[dst..dst + c].copy_from_slice(img.get(r, col));
            } // Zeros misses stay 0.0
        }
    }
    out
}

/// A sliding-neighborhood kernel: given the `win×win×C` neighborhood
/// (row-major, interleaved), produce the output pixel's `C'` samples into
/// `out`. Must be `Sync` (called concurrently from workers).
pub trait NeighborhoodOp: Sync {
    /// Output channel count for a given input channel count.
    fn out_channels(&self, in_channels: usize) -> usize;
    /// Window edge length (odd).
    fn window(&self) -> usize;
    fn apply(&self, neighborhood: &[f32], in_channels: usize, out: &mut [f32]);
}

/// Mean (box) filter over the window, per band.
pub struct MeanFilter {
    pub window: usize,
}

impl NeighborhoodOp for MeanFilter {
    fn out_channels(&self, in_channels: usize) -> usize {
        in_channels
    }
    fn window(&self) -> usize {
        self.window
    }
    fn apply(&self, nb: &[f32], c: usize, out: &mut [f32]) {
        let n = (nb.len() / c) as f32;
        out.fill(0.0);
        for px in nb.chunks_exact(c) {
            for (b, &v) in px.iter().enumerate() {
                out[b] += v;
            }
        }
        for v in out.iter_mut() {
            *v /= n;
        }
    }
}

/// Median filter per band (the paper's cited pre-processing staple).
pub struct MedianFilter {
    pub window: usize,
}

impl NeighborhoodOp for MedianFilter {
    fn out_channels(&self, in_channels: usize) -> usize {
        in_channels
    }
    fn window(&self) -> usize {
        self.window
    }
    fn apply(&self, nb: &[f32], c: usize, out: &mut [f32]) {
        let n = nb.len() / c;
        let mut band = Vec::with_capacity(n);
        for b in 0..c {
            band.clear();
            band.extend(nb.iter().skip(b).step_by(c).copied());
            band.sort_by(|x, y| x.partial_cmp(y).unwrap());
            out[b] = if n % 2 == 1 {
                band[n / 2]
            } else {
                (band[n / 2 - 1] + band[n / 2]) / 2.0
            };
        }
    }
}

/// Sobel gradient magnitude (on band 0; classic edge pre-pass).
pub struct SobelMagnitude;

impl NeighborhoodOp for SobelMagnitude {
    fn out_channels(&self, _in: usize) -> usize {
        1
    }
    fn window(&self) -> usize {
        3
    }
    fn apply(&self, nb: &[f32], c: usize, out: &mut [f32]) {
        let v = |r: usize, col: usize| nb[(r * 3 + col) * c];
        let gx = (v(0, 2) + 2.0 * v(1, 2) + v(2, 2)) - (v(0, 0) + 2.0 * v(1, 0) + v(2, 0));
        let gy = (v(2, 0) + 2.0 * v(2, 1) + v(2, 2)) - (v(0, 0) + 2.0 * v(0, 1) + v(0, 2));
        out[0] = (gx * gx + gy * gy).sqrt();
    }
}

/// Apply `op` over the whole image with distinct-block parallelism:
/// blocks of `plan` are processed by `workers` scoped threads, each
/// reading its block + halo via [`padded_crop`] and writing its interior
/// into the output. Block-plan and worker-count invariant (tested).
pub fn sliding_apply(
    img: &Raster,
    plan: &BlockPlan,
    op: &dyn NeighborhoodOp,
    pad: PadMethod,
    workers: usize,
) -> Raster {
    assert!(workers > 0);
    assert_eq!(plan.height(), img.height());
    assert_eq!(plan.width(), img.width());
    let win = op.window();
    assert!(win % 2 == 1, "window must be odd, got {win}");
    let halo = win / 2;
    let c_in = img.channels();
    let c_out = op.out_channels(c_in);
    let mut out = Raster::zeros(img.height(), img.width(), c_out);

    // Work queue: block indices; output rows are disjoint per block, but
    // rust can't see that through a flat buffer — collect per-block
    // results and scatter single-threaded (scatter is memcpy-cheap).
    let next = Mutex::new(0usize);
    let results: Mutex<Vec<(usize, Vec<f32>)>> = Mutex::new(Vec::with_capacity(plan.len()));
    std::thread::scope(|scope| {
        for _ in 0..workers.min(plan.len().max(1)) {
            scope.spawn(|| {
                let mut nb = vec![0.0f32; win * win * c_in];
                let mut px_out = vec![0.0f32; c_out];
                loop {
                    let bi = {
                        let mut g = next.lock().unwrap();
                        if *g >= plan.len() {
                            return;
                        }
                        let i = *g;
                        *g += 1;
                        i
                    };
                    let region = plan.region(bi);
                    let tile = padded_crop(img, region, halo, pad);
                    let tile_cols = region.cols() + 2 * halo;
                    let mut block_out = vec![0.0f32; region.area() * c_out];
                    for r in 0..region.rows() {
                        for col in 0..region.cols() {
                            // gather the win×win neighborhood from the tile
                            for wr in 0..win {
                                let src = ((r + wr) * tile_cols + col) * c_in;
                                let dst = wr * win * c_in;
                                nb[dst..dst + win * c_in]
                                    .copy_from_slice(&tile[src..src + win * c_in]);
                            }
                            op.apply(&nb, c_in, &mut px_out);
                            let dst = (r * region.cols() + col) * c_out;
                            block_out[dst..dst + c_out].copy_from_slice(&px_out);
                        }
                    }
                    results.lock().unwrap().push((bi, block_out));
                }
            });
        }
    });

    // scatter
    for (bi, block_out) in results.into_inner().unwrap() {
        let region = plan.region(bi);
        for r in 0..region.rows() {
            let src = r * region.cols() * c_out;
            let dst_row = region.row0 + r;
            for col in 0..region.cols() {
                let s = src + col * c_out;
                out.set(dst_row, region.col0 + col, &block_out[s..s + c_out]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::image::SyntheticOrtho;

    #[test]
    fn resolve_replicate_and_symmetric() {
        assert_eq!(resolve(-1, 5, PadMethod::Replicate), Some(0));
        assert_eq!(resolve(7, 5, PadMethod::Replicate), Some(4));
        assert_eq!(resolve(-1, 5, PadMethod::Symmetric), Some(0));
        assert_eq!(resolve(-2, 5, PadMethod::Symmetric), Some(1));
        assert_eq!(resolve(5, 5, PadMethod::Symmetric), Some(4));
        assert_eq!(resolve(6, 5, PadMethod::Symmetric), Some(3));
        assert_eq!(resolve(-1, 5, PadMethod::Zeros), None);
        assert_eq!(resolve(2, 5, PadMethod::Zeros), Some(2));
    }

    #[test]
    fn padded_crop_interior_matches_plain_crop() {
        let img = SyntheticOrtho::default().with_seed(3).generate(12, 14);
        let region = BlockRegion::new(4, 5, 3, 4);
        let halo = 2;
        let padded = padded_crop(&img, &region, halo, PadMethod::Replicate);
        let cols = region.cols() + 2 * halo;
        let c = img.channels();
        // interior of the padded tile == direct crop
        let plain = img.crop(&region);
        for r in 0..region.rows() {
            for col in 0..region.cols() {
                let p = ((r + halo) * cols + (col + halo)) * c;
                let q = (r * region.cols() + col) * c;
                assert_eq!(&padded[p..p + c], &plain[q..q + c]);
            }
        }
    }

    #[test]
    fn zeros_padding_is_zero_outside() {
        let img = SyntheticOrtho::default().with_seed(4).generate(6, 6);
        let region = BlockRegion::new(0, 0, 2, 2);
        let padded = padded_crop(&img, &region, 1, PadMethod::Zeros);
        // top-left corner of the tile is outside the image
        assert_eq!(&padded[..3], &[0.0, 0.0, 0.0]);
    }

    fn reference_mean(img: &Raster, win: usize, pad: PadMethod) -> Raster {
        // single-block, single-worker = the trivially correct path
        let plan = BlockPlan::new(
            img.height(),
            img.width(),
            BlockShape::Custom {
                rows: img.height(),
                cols: img.width(),
            },
        );
        sliding_apply(img, &plan, &MeanFilter { window: win }, pad, 1)
    }

    #[test]
    fn sliding_is_plan_and_worker_invariant() {
        let img = SyntheticOrtho::default().with_seed(5).generate(20, 26);
        let want = reference_mean(&img, 3, PadMethod::Symmetric);
        for shape in [
            BlockShape::Square { side: 7 },
            BlockShape::Rows { band_rows: 6 },
            BlockShape::Cols { band_cols: 9 },
        ] {
            for workers in [1usize, 3] {
                let plan = BlockPlan::new(20, 26, shape);
                let got = sliding_apply(
                    &img,
                    &plan,
                    &MeanFilter { window: 3 },
                    PadMethod::Symmetric,
                    workers,
                );
                assert_eq!(got, want, "{shape}/{workers} diverged");
            }
        }
    }

    #[test]
    fn mean_filter_flattens_constant_image() {
        let mut img = Raster::zeros(8, 8, 1);
        img.data_mut().fill(42.0);
        let plan = BlockPlan::new(8, 8, BlockShape::Square { side: 4 });
        let out = sliding_apply(&img, &plan, &MeanFilter { window: 5 }, PadMethod::Replicate, 2);
        assert!(out.data().iter().all(|&v| (v - 42.0).abs() < 1e-4));
    }

    #[test]
    fn median_filter_kills_salt_noise() {
        let mut img = Raster::zeros(9, 9, 1);
        img.data_mut().fill(10.0);
        img.set(4, 4, &[255.0]); // salt pixel
        let plan = BlockPlan::new(9, 9, BlockShape::Rows { band_rows: 3 });
        let out = sliding_apply(&img, &plan, &MedianFilter { window: 3 }, PadMethod::Replicate, 2);
        assert_eq!(out.get(4, 4)[0], 10.0, "median must remove the outlier");
    }

    #[test]
    fn sobel_detects_vertical_edge() {
        let mut img = Raster::zeros(8, 8, 3);
        for r in 0..8 {
            for c in 4..8 {
                img.set(r, c, &[100.0, 100.0, 100.0]);
            }
        }
        let plan = BlockPlan::new(8, 8, BlockShape::Square { side: 4 });
        let out = sliding_apply(&img, &plan, &SobelMagnitude, PadMethod::Replicate, 2);
        assert_eq!(out.channels(), 1);
        // strong response along the edge column, none far from it
        assert!(out.get(4, 4)[0] > 100.0);
        assert!(out.get(4, 6)[0] < 1.0);
    }

    #[test]
    #[should_panic(expected = "window must be odd")]
    fn even_window_rejected() {
        let img = SyntheticOrtho::default().generate(8, 8);
        let plan = BlockPlan::new(8, 8, BlockShape::Square { side: 4 });
        sliding_apply(&img, &plan, &MeanFilter { window: 4 }, PadMethod::Zeros, 1);
    }
}
