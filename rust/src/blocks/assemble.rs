//! Reassembly: scatter per-block results back into the output label map.
//!
//! `blockproc`'s final step — block results land back at their region's
//! offsets. The assembler tracks coverage so a missing or duplicate block
//! is a hard error rather than silent corruption.

use super::region::BlockRegion;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum AssembleError {
    #[error("block {0} exceeds image bounds {1}x{2}")]
    OutOfBounds(BlockRegion, usize, usize),
    #[error("block {0} placed twice")]
    Duplicate(BlockRegion),
    #[error("block {0} overlaps previously placed block {1}")]
    Overlap(BlockRegion, BlockRegion),
    #[error("label buffer for {0} has {1} entries, block area is {2}")]
    WrongSize(BlockRegion, usize, usize),
    #[error("assembly incomplete: {written}/{total} pixels written")]
    Incomplete { written: usize, total: usize },
}

/// The bookkeeping every assembler shares: bounds/size validation,
/// duplicate *and* overlap rejection, and exactly-once coverage. The
/// in-memory [`LabelAssembler`] and the spill-backed
/// [`super::sink::SpillAssembler`] both claim regions through one
/// `Coverage`, so their error behaviour cannot drift.
///
/// Placed regions are indexed by starting row so a claim only compares
/// against regions whose row span can reach it — on the streaming
/// path's strip-tall row plans (thousands of blocks on a tall image)
/// that is O(1) amortized per claim instead of a full O(B) scan.
#[derive(Clone, Debug)]
pub(crate) struct Coverage {
    height: usize,
    width: usize,
    written: usize,
    /// Placed regions, keyed by `row0`.
    placed: std::collections::BTreeMap<usize, Vec<BlockRegion>>,
    /// Tallest region seen (bounds the backward row-range scan).
    max_rows: usize,
}

impl Coverage {
    pub(crate) fn new(height: usize, width: usize) -> Coverage {
        Coverage {
            height,
            width,
            written: 0,
            placed: std::collections::BTreeMap::new(),
            max_rows: 0,
        }
    }

    /// Validate and record one region; errors leave the coverage
    /// untouched.
    pub(crate) fn claim(
        &mut self,
        region: &BlockRegion,
        labels_len: usize,
    ) -> Result<(), AssembleError> {
        if region.row_end() > self.height || region.col_end() > self.width {
            return Err(AssembleError::OutOfBounds(*region, self.height, self.width));
        }
        if labels_len != region.area() {
            return Err(AssembleError::WrongSize(*region, labels_len, region.area()));
        }
        // A placed region can only intersect if its row0 lies within
        // max_rows - 1 rows above region.row0, or anywhere inside the
        // region's own row span.
        let lo = region.row0.saturating_sub(self.max_rows.saturating_sub(1));
        for (_, bucket) in self.placed.range(lo..region.row_end()) {
            for prev in bucket {
                if prev == region {
                    return Err(AssembleError::Duplicate(*region));
                }
                if prev.intersects(region) {
                    return Err(AssembleError::Overlap(*region, *prev));
                }
            }
        }
        self.placed.entry(region.row0).or_default().push(*region);
        self.max_rows = self.max_rows.max(region.rows());
        self.written += region.area();
        Ok(())
    }

    pub(crate) fn written(&self) -> usize {
        self.written
    }

    /// Fraction of the image covered so far.
    pub(crate) fn fraction(&self) -> f64 {
        self.written as f64 / (self.height * self.width) as f64
    }

    /// Exactly-once completeness check (overlap rejection at claim time
    /// makes `written == total` equivalent to full coverage).
    pub(crate) fn finish_check(&self) -> Result<(), AssembleError> {
        let total = self.height * self.width;
        if self.written != total {
            return Err(AssembleError::Incomplete {
                written: self.written,
                total,
            });
        }
        Ok(())
    }
}

/// Accumulates per-block label buffers into a full `height×width` map.
#[derive(Clone, Debug)]
pub struct LabelAssembler {
    width: usize,
    labels: Vec<u32>,
    coverage: Coverage,
}

impl LabelAssembler {
    pub fn new(height: usize, width: usize) -> LabelAssembler {
        LabelAssembler {
            width,
            labels: vec![u32::MAX; height * width],
            coverage: Coverage::new(height, width),
        }
    }

    /// Place one block's labels (row-major within the region). A region
    /// that duplicates or merely *overlaps* an earlier placement is a
    /// hard error — silent overwrites were possible before overlap
    /// tracking and would have corrupted coverage accounting.
    pub fn place(&mut self, region: &BlockRegion, labels: &[u32]) -> Result<(), AssembleError> {
        self.coverage.claim(region, labels.len())?;
        for (ri, r) in (region.row0..region.row_end()).enumerate() {
            let src = &labels[ri * region.cols()..(ri + 1) * region.cols()];
            let dst_start = r * self.width + region.col0;
            self.labels[dst_start..dst_start + region.cols()].copy_from_slice(src);
        }
        Ok(())
    }

    /// Fraction of the image covered so far.
    pub fn coverage(&self) -> f64 {
        self.coverage.fraction()
    }

    /// Pixels placed so far (the label-sink cursor checkpoints record).
    pub fn written(&self) -> usize {
        self.coverage.written()
    }

    /// Finish: every pixel must have been written exactly once.
    pub fn finish(self) -> Result<Vec<u32>, AssembleError> {
        self.coverage.finish_check()?;
        Ok(self.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};

    #[test]
    fn assemble_identity_round_trip() {
        // labels[i] = linear pixel index; partition + reassemble = identity
        let (h, w) = (7, 9);
        let plan = BlockPlan::new(h, w, BlockShape::Square { side: 3 });
        let mut asm = LabelAssembler::new(h, w);
        for region in plan.iter() {
            let mut buf = Vec::with_capacity(region.area());
            for r in region.row0..region.row_end() {
                for c in region.col0..region.col_end() {
                    buf.push((r * w + c) as u32);
                }
            }
            asm.place(region, &buf).unwrap();
        }
        let out = asm.finish().unwrap();
        let want: Vec<u32> = (0..(h * w) as u32).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn duplicate_block_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        let r = BlockRegion::new(0, 0, 2, 2);
        asm.place(&r, &[0; 4]).unwrap();
        assert_eq!(asm.place(&r, &[0; 4]), Err(AssembleError::Duplicate(r)));
    }

    #[test]
    fn overlapping_block_rejected() {
        // A different-origin region that intersects an earlier one used
        // to silently overwrite; now it is a hard error and the failed
        // placement leaves coverage untouched.
        let mut asm = LabelAssembler::new(4, 4);
        let a = BlockRegion::new(0, 0, 2, 2);
        asm.place(&a, &[7; 4]).unwrap();
        let b = BlockRegion::new(1, 1, 2, 2);
        assert_eq!(asm.place(&b, &[9; 4]), Err(AssembleError::Overlap(b, a)));
        assert!((asm.coverage() - 0.25).abs() < 1e-12, "failed place must not count");
        // non-overlapping neighbours still fine
        asm.place(&BlockRegion::new(0, 2, 2, 2), &[1; 4]).unwrap();
        asm.place(&BlockRegion::new(2, 0, 2, 4), &[2; 8]).unwrap();
        let out = asm.finish().unwrap();
        assert_eq!(out[0], 7);
        assert_eq!(out[3], 1);
        assert_eq!(out[15], 2);
    }

    #[test]
    fn wrong_size_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        let r = BlockRegion::new(0, 0, 2, 2);
        assert!(matches!(
            asm.place(&r, &[0; 3]),
            Err(AssembleError::WrongSize(..))
        ));
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        let r = BlockRegion::new(3, 3, 2, 2);
        assert!(matches!(
            asm.place(&r, &[0; 4]),
            Err(AssembleError::OutOfBounds(..))
        ));
    }

    #[test]
    fn incomplete_finish_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        asm.place(&BlockRegion::new(0, 0, 2, 4), &[1; 8]).unwrap();
        assert!((asm.coverage() - 0.5).abs() < 1e-12);
        assert_eq!(
            asm.finish(),
            Err(AssembleError::Incomplete {
                written: 8,
                total: 16
            })
        );
    }
}
