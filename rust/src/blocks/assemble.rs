//! Reassembly: scatter per-block results back into the output label map.
//!
//! `blockproc`'s final step — block results land back at their region's
//! offsets. The assembler tracks coverage so a missing or duplicate block
//! is a hard error rather than silent corruption.

use super::region::BlockRegion;

/// Accumulates per-block label buffers into a full `height×width` map.
#[derive(Clone, Debug)]
pub struct LabelAssembler {
    height: usize,
    width: usize,
    labels: Vec<u32>,
    /// Count of pixels written (each exactly once when complete).
    written: usize,
    /// Per-block-origin guard against double placement.
    placed: std::collections::BTreeSet<(usize, usize)>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum AssembleError {
    #[error("block {0} exceeds image bounds {1}x{2}")]
    OutOfBounds(BlockRegion, usize, usize),
    #[error("block {0} placed twice")]
    Duplicate(BlockRegion),
    #[error("label buffer for {0} has {1} entries, block area is {2}")]
    WrongSize(BlockRegion, usize, usize),
    #[error("assembly incomplete: {written}/{total} pixels written")]
    Incomplete { written: usize, total: usize },
}

impl LabelAssembler {
    pub fn new(height: usize, width: usize) -> LabelAssembler {
        LabelAssembler {
            height,
            width,
            labels: vec![u32::MAX; height * width],
            written: 0,
            placed: Default::default(),
        }
    }

    /// Place one block's labels (row-major within the region).
    pub fn place(&mut self, region: &BlockRegion, labels: &[u32]) -> Result<(), AssembleError> {
        if region.row_end() > self.height || region.col_end() > self.width {
            return Err(AssembleError::OutOfBounds(*region, self.height, self.width));
        }
        if labels.len() != region.area() {
            return Err(AssembleError::WrongSize(*region, labels.len(), region.area()));
        }
        if !self.placed.insert((region.row0, region.col0)) {
            return Err(AssembleError::Duplicate(*region));
        }
        for (ri, r) in (region.row0..region.row_end()).enumerate() {
            let src = &labels[ri * region.cols()..(ri + 1) * region.cols()];
            let dst_start = r * self.width + region.col0;
            self.labels[dst_start..dst_start + region.cols()].copy_from_slice(src);
        }
        self.written += region.area();
        Ok(())
    }

    /// Fraction of the image covered so far.
    pub fn coverage(&self) -> f64 {
        self.written as f64 / (self.height * self.width) as f64
    }

    /// Finish: every pixel must have been written exactly once.
    pub fn finish(self) -> Result<Vec<u32>, AssembleError> {
        let total = self.height * self.width;
        if self.written != total {
            return Err(AssembleError::Incomplete {
                written: self.written,
                total,
            });
        }
        Ok(self.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};

    #[test]
    fn assemble_identity_round_trip() {
        // labels[i] = linear pixel index; partition + reassemble = identity
        let (h, w) = (7, 9);
        let plan = BlockPlan::new(h, w, BlockShape::Square { side: 3 });
        let mut asm = LabelAssembler::new(h, w);
        for region in plan.iter() {
            let mut buf = Vec::with_capacity(region.area());
            for r in region.row0..region.row_end() {
                for c in region.col0..region.col_end() {
                    buf.push((r * w + c) as u32);
                }
            }
            asm.place(region, &buf).unwrap();
        }
        let out = asm.finish().unwrap();
        let want: Vec<u32> = (0..(h * w) as u32).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn duplicate_block_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        let r = BlockRegion::new(0, 0, 2, 2);
        asm.place(&r, &[0; 4]).unwrap();
        assert_eq!(asm.place(&r, &[0; 4]), Err(AssembleError::Duplicate(r)));
    }

    #[test]
    fn wrong_size_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        let r = BlockRegion::new(0, 0, 2, 2);
        assert!(matches!(
            asm.place(&r, &[0; 3]),
            Err(AssembleError::WrongSize(..))
        ));
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        let r = BlockRegion::new(3, 3, 2, 2);
        assert!(matches!(
            asm.place(&r, &[0; 4]),
            Err(AssembleError::OutOfBounds(..))
        ));
    }

    #[test]
    fn incomplete_finish_rejected() {
        let mut asm = LabelAssembler::new(4, 4);
        asm.place(&BlockRegion::new(0, 0, 2, 4), &[1; 8]).unwrap();
        assert!((asm.coverage() - 0.5).abs() < 1e-12);
        assert_eq!(
            asm.finish(),
            Err(AssembleError::Incomplete {
                written: 8,
                total: 16
            })
        );
    }
}
