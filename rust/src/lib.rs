//! # blockms — parallel block processing for K-Means over satellite imagery
//!
//! A three-layer reproduction of *"Analysis of Different Approaches of
//! Parallel Block Processing for K-Means Clustering Algorithm"*
//! (Rashmi C, CS.DC 2017):
//!
//! - **L3 (this crate)** — the coordinator: shape-parameterized block
//!   plans ([`blocks`]), a strip-granular image store reproducing MATLAB
//!   `blockproc` I/O behaviour ([`stripstore`]), an execution planner
//!   that resolves every run into one cost-model-chosen [`plan::ExecPlan`]
//!   ([`plan`]), a leader/worker SPMD pool ([`coordinator`]), a
//!   leader/shard-worker split that distributes the same round protocol
//!   across OS processes over a versioned wire format ([`shard`]), a
//!   persistent multi-job serving layer that drives many clustering jobs
//!   over one shared pool with admission control ([`service`]), an
//!   amortized multi-variant sweep layer that runs a `(k, seed, init)`
//!   grid over one image with a single decode pass ([`sweep`]), a
//!   discrete-event worker simulator for speedup studies ([`simtime`]),
//!   the sequential baseline ([`kmeans`]), and the paper-table bench
//!   harness ([`bench`]).
//! - **L2/L1 (python, build-time only)** — JAX graphs + Pallas kernels
//!   AOT-lowered to `artifacts/*.hlo.txt`, loaded and executed through
//!   PJRT by [`runtime`]. Python never runs on the request path.
//!
//! See `examples/quickstart.rs` for the 20-line tour, and DESIGN.md for
//! the paper-to-module map.

pub mod bench;
pub mod blocks;
pub mod cli;
pub mod coordinator;
pub mod image;
pub mod kmeans;
pub mod metrics;
pub mod plan;
pub mod resilience;
pub mod runtime;
pub mod service;
pub mod shard;
pub mod simtime;
pub mod stripstore;
pub mod sweep;
pub mod util;

/// Convenient re-exports of the types most programs need.
pub mod prelude {
    pub use crate::blocks::{BlockPlan, BlockRegion, BlockShape, LabelMap, LabelSink};
    pub use crate::coordinator::{
        ClusterConfig, ClusterMode, ClusterOutput, Coordinator, CoordinatorConfig, Engine,
        StreamRun,
    };
    pub use crate::image::{PpmSource, Raster, RasterSource, SyntheticOrtho, SyntheticSource};
    pub use crate::kmeans::{
        InitMethod, KernelChoice, SeqKMeans, SoaTile, StreamInit, TileArena, TileLayout,
    };
    pub use crate::metrics::{RunTimer, Speedup};
    pub use crate::plan::{CostModel, ExecPlan, Explain, Planner, PlanRequest};
    pub use crate::resilience::{
        Checkpoint, FaultKind, FaultPlan, Stall, Watchdog, DEFAULT_HEARTBEAT_TIMEOUT_MS,
    };
    pub use crate::service::{
        ClusterServer, DrainReport, JobHandle, JobInput, JobSpec, JobStatus, ServerConfig,
    };
    pub use crate::shard::{ShardEndpoints, ShardSpec, ShardTransport};
    pub use crate::simtime::{SimParams, WorkerSim};
    pub use crate::stripstore::StripStore;
    pub use crate::sweep::{SweepGrid, SweepReport, SweepVariant};
}
