//! The leader's handle on its SPMD worker pool.

use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::messages::{Job, JobOutcome};
use super::queue::{JobQueue, Schedule};
use super::worker::{worker_main, WorkerContext};

/// A pool of worker threads processing block jobs round by round.
/// Rounds are synchronous at the leader (K-Means iterations are globally
/// sequential — centroids for round `r+1` need all of round `r`), matching
/// the paper's per-iteration barrier.
pub struct WorkerPool {
    queue: Arc<JobQueue>,
    results: Receiver<Result<JobOutcome>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` threads, each building its own compute backend
    /// from `ctx.backend` (PJRT clients are per-worker by necessity —
    /// and by design: it is the parpool model).
    pub fn spawn(workers: usize, ctx: WorkerContext, schedule: Schedule) -> WorkerPool {
        assert!(workers > 0, "need at least one worker");
        let queue = Arc::new(JobQueue::new(workers, schedule));
        let (tx, rx) = channel();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let queue = Arc::clone(&queue);
            let ctx = ctx.clone();
            let tx = tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("blockms-worker-{w}"))
                    .spawn(move || worker_main(w, ctx, queue, tx))
                    .expect("spawn worker thread"),
            );
        }
        WorkerPool {
            queue,
            results: rx,
            handles,
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute one round of jobs, blocking until all results arrive.
    /// Outcomes are returned sorted by block index (deterministic
    /// downstream reduction regardless of completion order). The first
    /// worker error aborts the round.
    pub fn run_round(&self, jobs: Vec<Job>) -> Result<Vec<JobOutcome>> {
        let expect = jobs.len();
        if expect == 0 {
            return Ok(Vec::new());
        }
        self.queue.push_round(jobs);
        let mut out = Vec::with_capacity(expect);
        for _ in 0..expect {
            match self.results.recv() {
                Ok(Ok(outcome)) => out.push(outcome),
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    return Err(anyhow!(
                        "worker pool hung up mid-round ({}/{} results)",
                        out.len(),
                        expect
                    ))
                }
            }
        }
        out.sort_by_key(|o| o.block);
        Ok(out)
    }

    /// Readiness barrier: one ping per worker, wait for all pongs.
    /// Absorbs worker startup cost (thread spawn + backend build — PJRT
    /// client construction and artifact compilation) so subsequent rounds
    /// time only steady-state work. Returns the barrier's wall seconds.
    pub fn warmup(&self) -> Result<f64> {
        let t0 = std::time::Instant::now();
        for w in 0..self.workers {
            self.queue.push_to_worker(
                w,
                Job {
                    block: usize::MAX,
                    round: 0,
                    payload: super::messages::JobPayload::Ping,
                },
            );
        }
        for _ in 0..self.workers {
            match self.results.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => return Err(anyhow!("worker pool hung up during warmup")),
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};
    use crate::coordinator::messages::{JobPayload, JobResult};
    use crate::coordinator::worker::BlockSource;
    use crate::image::SyntheticOrtho;
    use crate::kmeans::math;
    use crate::runtime::BackendSpec;

    fn context(fail_block: Option<usize>) -> (WorkerContext, Arc<crate::image::Raster>) {
        let img = Arc::new(SyntheticOrtho::default().with_seed(11).generate(48, 40));
        let plan = Arc::new(BlockPlan::new(48, 40, BlockShape::Square { side: 16 }));
        let ctx = WorkerContext {
            plan,
            source: BlockSource::Direct(Arc::clone(&img)),
            backend: BackendSpec::Native {
                k: 2,
                channels: 3,
                local_iters: 4,
            },
            fail_block,
            local_mode: false,
            kernel: crate::kmeans::kernel::KernelChoice::Naive,
        };
        (ctx, img)
    }

    fn step_jobs(n: usize, centroids: &Arc<Vec<f32>>) -> Vec<Job> {
        (0..n)
            .map(|b| Job {
                block: b,
                round: 1,
                payload: JobPayload::Step {
                    centroids: Arc::clone(centroids),
                    drift: None,
                },
            })
            .collect()
    }

    #[test]
    fn round_results_cover_all_blocks_sorted() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(3, ctx, Schedule::Dynamic);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool.run_round(step_jobs(nblocks, &cen)).unwrap();
        assert_eq!(outcomes.len(), nblocks);
        let blocks: Vec<usize> = outcomes.iter().map(|o| o.block).collect();
        assert_eq!(blocks, (0..nblocks).collect::<Vec<_>>());
        pool.shutdown();
    }

    #[test]
    fn parallel_reduction_equals_whole_image_step() {
        let (ctx, img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(4, ctx, Schedule::Dynamic);
        let cen_v = vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0];
        let cen = Arc::new(cen_v.clone());
        let outcomes = pool.run_round(step_jobs(nblocks, &cen)).unwrap();
        let mut merged = math::StepAccum::zeros(2, 3);
        for o in &outcomes {
            match &o.result {
                JobResult::Step { accum } => merged.merge(accum),
                _ => unreachable!(),
            }
        }
        let whole = math::step(img.as_pixels(), &cen_v, 2, 3);
        assert_eq!(merged.counts, whole.counts);
        for (a, b) in merged.sums.iter().zip(&whole.sums) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert!((merged.inertia - whole.inertia).abs() < 1e-3);
        pool.shutdown();
    }

    #[test]
    fn injected_failure_propagates() {
        let (ctx, _img) = context(Some(2));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, ctx, Schedule::Dynamic);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool.run_round(step_jobs(nblocks, &cen)).unwrap_err();
        assert!(err.to_string().contains("injected failure"), "{err}");
        pool.shutdown();
    }

    #[test]
    fn multiple_rounds_reuse_workers() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, ctx, Schedule::Static);
        let cen = Arc::new(vec![0.0, 0.0, 0.0, 255.0, 255.0, 255.0]);
        for round in 0..3 {
            let outcomes = pool.run_round(step_jobs(nblocks, &cen)).unwrap();
            assert_eq!(outcomes.len(), nblocks, "round {round}");
        }
        pool.shutdown();
    }

    #[test]
    fn static_schedule_uses_all_workers() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        assert!(nblocks >= 4);
        let pool = WorkerPool::spawn(2, ctx, Schedule::Static);
        let cen = Arc::new(vec![0.0; 6]);
        let outcomes = pool.run_round(step_jobs(nblocks, &cen)).unwrap();
        let w0 = outcomes.iter().filter(|o| o.worker == 0).count();
        let w1 = outcomes.iter().filter(|o| o.worker == 1).count();
        assert_eq!(w0 + w1, nblocks);
        assert!(w0 > 0 && w1 > 0, "static split degenerate: {w0}/{w1}");
        pool.shutdown();
    }

    #[test]
    fn empty_round_is_noop() {
        let (ctx, _img) = context(None);
        let pool = WorkerPool::spawn(1, ctx, Schedule::Dynamic);
        assert!(pool.run_round(Vec::new()).unwrap().is_empty());
        pool.shutdown();
    }
}
