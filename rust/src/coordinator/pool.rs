//! The leader's handle on its SPMD worker pool.
//!
//! A pool is spawned **once** and can serve many clustering jobs over
//! its lifetime: jobs register a [`WorkerContext`] under their
//! [`JobId`], submit tagged block jobs, receive tagged outcomes, and
//! retire when done (dropping worker-side cached state). Single-run
//! callers use the [`WorkerPool::run_round`] barrier, which keeps the
//! paper's per-iteration synchronous semantics; the service layer uses
//! the streaming [`WorkerPool::submit`]/[`WorkerPool::recv_result`]
//! pair to interleave rounds of many jobs at once.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::messages::{Job, JobError, JobId, JobOutcome, JobPayload};
use super::queue::{JobQueue, Schedule};
use super::worker::{panic_message, worker_main, ContextRegistry, WorkerContext};
use crate::resilience::{Stall, Watchdog, DEFAULT_HEARTBEAT_TIMEOUT_MS};
use crate::shard::proxy::{proxy_main, ShardSpecMap};
use crate::shard::{ShardSpec, ShardTransport};

/// How often a waiting leader wakes to scan the heartbeat table.
const WATCHDOG_TICK: Duration = Duration::from_millis(25);

/// Warmup pongs may legitimately take a long time (PJRT client build +
/// artifact compile), so the readiness barrier gets its own generous
/// bound instead of the block-level heartbeat timeout.
const WARMUP_TIMEOUT: Duration = Duration::from_secs(300);

/// Straggler speculation fires only once this fraction of the round has
/// completed (the median block time is meaningful by then).
const SPECULATE_ROUND_FRACTION: f64 = 0.75;

/// A block is a straggler when the round has been running longer than
/// this multiple of the median completed-block arrival time.
const SPECULATE_MULTIPLIER: f64 = 4.0;

/// Floor on the straggler threshold: never speculate inside the noise
/// band of scheduler jitter.
const SPECULATE_MIN_SECS: f64 = 0.025;

/// A pool of worker threads processing tagged block jobs.
pub struct WorkerPool {
    queue: Arc<JobQueue>,
    registry: Arc<ContextRegistry>,
    results: Receiver<Result<JobOutcome, JobError>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    /// High water of simultaneously registered jobs (instrumentation
    /// backing the admission-cap assertions).
    open_high_water: AtomicUsize,
    /// The last panic that escaped a worker loop (the supervisor
    /// records it before respawning). When the pool hangs up, this is
    /// the root cause the leader forwards instead of a bare
    /// "worker pool hung up".
    last_panic: Arc<Mutex<Option<String>>>,
    /// Heartbeat table: workers stamp per block visit, the leader's
    /// bounded barriers scan it for silent workers.
    watchdog: Arc<Watchdog>,
    /// Straggler speculation for [`WorkerPool::run_round_resilient`]
    /// (off by default; see [`crate::plan::ExecPlan::speculate`]).
    speculate: AtomicBool,
    /// Stalls scanned but not yet surfaced to a caller (one is
    /// delivered per `recv_result*` call; the rest wait here).
    pending_stalls: Mutex<VecDeque<Stall>>,
    /// Shard specs proxies ship on first contact per connection
    /// (sharded pools only; empty and unused for in-process pools).
    shard_specs: Arc<ShardSpecMap>,
}

impl WorkerPool {
    /// Spawn `workers` threads. Workers build per-job compute backends
    /// lazily from the registered contexts (PJRT clients are per-worker
    /// by necessity — and by design: it is the parpool model).
    ///
    /// Each thread runs a **supervisor loop**: `worker_main` already
    /// converts per-block panics into [`JobError`]s, but if a panic
    /// ever escapes the loop itself (a bug outside block dispatch),
    /// the supervisor records the message and re-enters `worker_main`
    /// with fresh worker-local state — the pool's capacity never
    /// decays to zero behind the leader's back.
    pub fn spawn(workers: usize, schedule: Schedule) -> WorkerPool {
        assert!(workers > 0, "need at least one worker");
        let queue = Arc::new(JobQueue::new(workers, schedule));
        let registry = Arc::new(ContextRegistry::new());
        let last_panic: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let watchdog = Arc::new(Watchdog::new(workers, DEFAULT_HEARTBEAT_TIMEOUT_MS));
        let (tx, rx) = channel();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let queue = Arc::clone(&queue);
            let registry = Arc::clone(&registry);
            let last_panic = Arc::clone(&last_panic);
            let watchdog = Arc::clone(&watchdog);
            let tx = tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("blockms-worker-{w}"))
                    .spawn(move || loop {
                        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker_main(
                                w,
                                Arc::clone(&registry),
                                Arc::clone(&queue),
                                tx.clone(),
                                Arc::clone(&watchdog),
                            )
                        }));
                        match caught {
                            // Clean exit: queue closed or leader gone.
                            Ok(()) => break,
                            Err(payload) => {
                                let msg = panic_message(payload.as_ref());
                                *last_panic.lock().unwrap() =
                                    Some(format!("worker {w} panicked: {msg}"));
                                // A panic mid-block leaves the heartbeat
                                // slot busy; clear it so the watchdog
                                // does not escalate the respawned (idle)
                                // worker.
                                watchdog.end(w);
                                // Respawn: re-enter the loop with fresh
                                // worker-local state (engines, bounds,
                                // tiles all rebuild lazily).
                            }
                        }
                    })
                    .expect("spawn worker thread"),
            );
        }
        WorkerPool {
            queue,
            registry,
            results: rx,
            handles,
            workers,
            open_high_water: AtomicUsize::new(0),
            last_panic,
            watchdog,
            speculate: AtomicBool::new(false),
            pending_stalls: Mutex::new(VecDeque::new()),
            shard_specs: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// Spawn a **sharded** pool: one proxy thread per transport, each
    /// forwarding blocks to a shard process instead of computing them
    /// (see [`crate::shard::proxy`]). The leader-side protocol —
    /// `run_round`, retry budgets, watchdog escalation, speculation —
    /// is identical to an in-process pool; only the worker bodies
    /// differ. Always dynamic scheduling: a static split would pin
    /// blocks to connections and defeat dead-shard re-queueing.
    ///
    /// Proxies are *not* respawned on failure (their transport died
    /// with them); the pool's capacity shrinks to the surviving
    /// connections, which is the intended shard-death behaviour.
    pub fn spawn_sharded(transports: Vec<Box<dyn ShardTransport + Send>>) -> WorkerPool {
        let workers = transports.len();
        assert!(workers > 0, "need at least one shard connection");
        let queue = Arc::new(JobQueue::new(workers, Schedule::Dynamic));
        let registry = Arc::new(ContextRegistry::new());
        let last_panic: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let watchdog = Arc::new(Watchdog::new(workers, DEFAULT_HEARTBEAT_TIMEOUT_MS));
        let shard_specs: Arc<ShardSpecMap> = Arc::new(Mutex::new(HashMap::new()));
        let (tx, rx) = channel();
        let mut handles = Vec::with_capacity(workers);
        for (w, transport) in transports.into_iter().enumerate() {
            let queue = Arc::clone(&queue);
            let watchdog = Arc::clone(&watchdog);
            let specs = Arc::clone(&shard_specs);
            let tx = tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("blockms-shard-proxy-{w}"))
                    .spawn(move || proxy_main(w, queue, tx, watchdog, specs, transport))
                    .expect("spawn shard proxy thread"),
            );
        }
        WorkerPool {
            queue,
            registry,
            results: rx,
            handles,
            workers,
            open_high_water: AtomicUsize::new(0),
            last_panic,
            watchdog,
            speculate: AtomicBool::new(false),
            pending_stalls: Mutex::new(VecDeque::new()),
            shard_specs,
        }
    }

    /// Register the spec proxies ship to shards for `job` (sharded
    /// pools; the shard analogue of [`WorkerPool::register_job`]).
    /// Must happen before the job's warmup ping or first block.
    pub fn register_shard_spec(&self, job: JobId, spec: Arc<ShardSpec>) {
        let fingerprint = spec.fingerprint();
        self.shard_specs.lock().unwrap().insert(job, (fingerprint, spec));
    }

    /// The pool's heartbeat table (tests and benches retune its
    /// staleness timeout through this).
    pub fn watchdog(&self) -> &Watchdog {
        &self.watchdog
    }

    /// Shorthand for retuning the heartbeat staleness timeout
    /// (milliseconds; 0 disables the watchdog).
    pub fn set_heartbeat_timeout_ms(&self, ms: u64) {
        self.watchdog.set_timeout_ms(ms);
    }

    /// Enable/disable straggler speculation in
    /// [`WorkerPool::run_round_resilient`].
    pub fn set_speculate(&self, on: bool) {
        self.speculate.store(on, Ordering::Relaxed);
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Register the per-job context workers will resolve `job`'s blocks
    /// against. Must happen before any of the job's blocks are
    /// submitted.
    pub fn register_job(&self, job: JobId, ctx: Arc<WorkerContext>) {
        let open = self.registry.register(job, ctx);
        self.open_high_water.fetch_max(open, Ordering::Relaxed);
    }

    /// Drop the job's registered context and tell every worker to shed
    /// its cached per-job state (backend, reader, pruned bounds). Call
    /// only after all of the job's in-flight outcomes have been
    /// received — a retire overtaking live blocks would fail them.
    ///
    /// Unshared jobs own their decoded arena tiles (content id == job
    /// id), so the plain retire purges them too. Share-group members
    /// must use [`WorkerPool::retire_job_with`] so only the group's
    /// last survivor evicts the shared content.
    pub fn retire_job(&self, job: JobId) {
        self.retire_job_with(job, Some(job));
    }

    /// [`WorkerPool::retire_job`] with explicit control over which
    /// arena *content* (if any) workers purge alongside the job's
    /// private state. `None` keeps shared tiles resident for the
    /// job's still-running share-group siblings.
    pub fn retire_job_with(&self, job: JobId, purge_content: Option<u64>) {
        self.registry.remove(job);
        self.shard_specs.lock().unwrap().remove(&job);
        self.queue.drop_job_group(job);
        for w in 0..self.workers {
            self.queue.push_to_worker(
                w,
                Job {
                    job,
                    block: usize::MAX,
                    round: 0,
                    payload: JobPayload::Retire { purge_content },
                },
            );
        }
    }

    /// Tag `job` as a member of rotation share group `group` (see
    /// [`JobQueue::set_job_group`]). Call alongside
    /// [`WorkerPool::register_job`], before the job's first submit.
    pub fn set_job_group(&self, job: JobId, group: u64) {
        self.queue.set_job_group(job, group);
    }

    /// Tag `job` with a QoS priority (see [`JobQueue::set_job_priority`]):
    /// the dynamic rotation drains higher-priority jobs first. Call
    /// alongside [`WorkerPool::register_job`], before the first submit.
    pub fn set_job_priority(&self, job: JobId, priority: usize) {
        self.queue.set_job_priority(job, priority);
    }

    /// Remove the job's queued (not yet popped) blocks; returns how many
    /// were removed so the leader can shrink its expected-outcome count.
    pub fn purge_job(&self, job: JobId) -> usize {
        self.queue.purge_job(job)
    }

    /// Enqueue tagged jobs without waiting for their outcomes (the
    /// service's streaming mode).
    pub fn submit(&self, jobs: Vec<Job>) {
        if !jobs.is_empty() {
            self.queue.push_round(jobs);
        }
    }

    /// The root cause of a pool hangup, if one was recorded: the last
    /// panic message that escaped a worker loop.
    pub fn hangup_cause(&self) -> Option<String> {
        self.last_panic.lock().unwrap().clone()
    }

    /// Build the pool-hangup error, attaching the recorded root cause
    /// (the actual worker panic) when there is one — a bare
    /// "worker pool hung up" is undiagnosable in a server log.
    fn hangup_error(&self, when: &str) -> anyhow::Error {
        match self.hangup_cause() {
            Some(cause) => anyhow!("worker pool hung up {when}: {cause}"),
            None => anyhow!("worker pool hung up {when}"),
        }
    }

    /// Receive the next outcome (any job). The outer `Err` means the
    /// pool itself hung up (all workers gone); the inner [`JobError`]
    /// is a per-job failure that leaves the pool serviceable.
    ///
    /// The wait is watchdog-bounded, not unconditional: if a busy
    /// worker goes silent past the heartbeat timeout, the stall is
    /// surfaced as a synthesized [`JobError`] naming the worker and
    /// block, so the service's existing retry path re-queues a spare
    /// copy instead of the leader blocking forever.
    pub fn recv_result(&self) -> Result<Result<JobOutcome, JobError>> {
        match self.recv_result_deadline(None)? {
            Some(r) => Ok(r),
            None => unreachable!("deadline-less recv cannot time out"),
        }
    }

    /// [`WorkerPool::recv_result`] with an optional deadline: returns
    /// `Ok(None)` once `until` passes with nothing received (the drain
    /// path's bounded wait). `None` waits indefinitely (still
    /// watchdog-scanned).
    pub fn recv_result_deadline(
        &self,
        until: Option<Instant>,
    ) -> Result<Option<Result<JobOutcome, JobError>>> {
        loop {
            if let Some(stall) = self.pending_stalls.lock().unwrap().pop_front() {
                return Ok(Some(Err(stall_error(&stall))));
            }
            let mut tick = WATCHDOG_TICK;
            if let Some(u) = until {
                let left = u.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    return Ok(None);
                }
                tick = tick.min(left);
            }
            match self.results.recv_timeout(tick) {
                Ok(r) => return Ok(Some(r)),
                Err(RecvTimeoutError::Timeout) => {
                    let mut stalls = self.watchdog.scan().into_iter();
                    if let Some(first) = stalls.next() {
                        self.pending_stalls.lock().unwrap().extend(stalls);
                        return Ok(Some(Err(stall_error(&first))));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(self.hangup_error("between results"))
                }
            }
        }
    }

    /// Execute one round of jobs, blocking until all results arrive.
    /// Outcomes are returned sorted by block index (deterministic
    /// downstream reduction regardless of completion order). The first
    /// worker error aborts the round. Assumes the caller is the only
    /// one with jobs in flight — multi-job leaders use
    /// [`WorkerPool::submit`] / [`WorkerPool::recv_result`] instead.
    pub fn run_round(&self, jobs: Vec<Job>) -> Result<Vec<JobOutcome>> {
        self.run_round_resilient(jobs, 0)
    }

    /// [`WorkerPool::run_round`] with a per-block retry budget. A
    /// failed block (worker error or caught panic) is re-enqueued up
    /// to `retries` times — via [`JobQueue::push_retry`], so placement
    /// follows the schedule — before the round aborts with the final
    /// error. The retried attempt recomputes from the same shipped
    /// centroids and the failing worker has already evicted its state
    /// for that `(job, block)`, so a recovered round is bit-identical
    /// to one that never failed (see [`crate::resilience`]).
    ///
    /// The barrier is **watchdog-bounded**: the leader waits in
    /// `recv_timeout` ticks and scans the heartbeat table between
    /// them. A busy worker silent past the timeout is escalated to the
    /// same re-queue path (a hung block is indistinguishable from a
    /// panicked one); with the retry budget exhausted the round aborts
    /// loudly instead of hanging forever. When speculation is enabled
    /// ([`WorkerPool::set_speculate`]) and the round is mostly done,
    /// straggling blocks are cloned onto idle workers and the first
    /// completed result wins. Both paths are bit-identical by
    /// construction: per-block work is a pure function of the round's
    /// shipped centroids, duplicates are discarded by
    /// `(job, block, round)` before the block-ordered reduction.
    pub fn run_round_resilient(&self, jobs: Vec<Job>, retries: usize) -> Result<Vec<JobOutcome>> {
        let expect = jobs.len();
        if expect == 0 {
            return Ok(Vec::new());
        }
        // Keep a clone of each block's job for re-enqueue (cheap: the
        // payload's centroids/drift are behind `Arc`s) and remember
        // the `(job, round)` tag a genuine outcome must carry — a late
        // twin from a previous round must never leak into this one.
        let spare: HashMap<usize, Job> = jobs.iter().map(|j| (j.block, j.clone())).collect();
        let mut attempts: HashMap<usize, usize> = HashMap::new();
        // Result copies in flight per block (original + escalations +
        // speculative clones). An error only counts against the retry
        // budget once every copy has failed.
        let mut copies: HashMap<usize, usize> = HashMap::new();
        let mut done: HashSet<usize> = HashSet::new();
        let mut speculated: HashSet<usize> = HashSet::new();
        let mut arrivals: Vec<f64> = Vec::with_capacity(expect);
        let t_round = Instant::now();
        self.queue.push_round(jobs);
        let mut out = Vec::with_capacity(expect);
        while out.len() < expect {
            match self.results.recv_timeout(WATCHDOG_TICK) {
                Ok(Ok(outcome)) => {
                    let genuine = spare
                        .get(&outcome.block)
                        .is_some_and(|j| j.job == outcome.job && j.round == outcome.round);
                    if !genuine || !done.insert(outcome.block) {
                        // Losing twin (block already reduced) or a
                        // stale outcome from an earlier round's hung
                        // worker: discard before reduction.
                        continue;
                    }
                    arrivals.push(t_round.elapsed().as_secs_f64());
                    out.push(outcome);
                }
                // Worker errors carry their own worker/block attribution.
                Ok(Err(e)) => {
                    if done.contains(&e.block) || !spare.contains_key(&e.block) {
                        continue; // a twin already won, or a stale error
                    }
                    let live = copies.entry(e.block).or_insert(1);
                    *live = live.saturating_sub(1);
                    if *live > 0 {
                        continue; // another copy of the block is still in flight
                    }
                    let used = attempts.entry(e.block).or_insert(0);
                    if *used < retries {
                        *used += 1;
                        *live = 1;
                        self.queue.push_retry(spare[&e.block].clone());
                    } else if retries == 0 {
                        return Err(e.error);
                    } else {
                        return Err(e.error.context(format!(
                            "block {} failed {} attempts (retry budget {retries})",
                            e.block,
                            *used + 1
                        )));
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    for stall in self.watchdog.scan() {
                        if done.contains(&stall.block) || !spare.contains_key(&stall.block) {
                            continue;
                        }
                        let used = attempts.entry(stall.block).or_insert(0);
                        if *used >= retries {
                            return Err(stall_error(&stall).error.context(format!(
                                "retry budget {retries} exhausted; raise --retries or \
                                 the heartbeat timeout"
                            )));
                        }
                        // Escalate: clone the hung block onto another
                        // worker. The parked original may still finish
                        // later — its duplicate result is discarded.
                        *used += 1;
                        *copies.entry(stall.block).or_insert(1) += 1;
                        self.queue.push_retry(spare[&stall.block].clone());
                    }
                    self.maybe_speculate(
                        expect,
                        &t_round,
                        &arrivals,
                        &spare,
                        &done,
                        &mut speculated,
                        &mut copies,
                    );
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(self
                        .hangup_error(&format!("mid-round ({}/{expect} results)", out.len())))
                }
            }
        }
        out.sort_by_key(|o| o.block);
        Ok(out)
    }

    /// Straggler speculation: once the round is mostly complete and
    /// has been running for a robust multiple of the median completed
    /// block time, clone every unfinished block onto the (now mostly
    /// idle) workers. At most one clone per block per round; the
    /// clones do not consume the retry budget — they recompute the
    /// same pure function, so the first result wins either way.
    #[allow(clippy::too_many_arguments)]
    fn maybe_speculate(
        &self,
        expect: usize,
        t_round: &Instant,
        arrivals: &[f64],
        spare: &HashMap<usize, Job>,
        done: &HashSet<usize>,
        speculated: &mut HashSet<usize>,
        copies: &mut HashMap<usize, usize>,
    ) {
        if !self.speculate.load(Ordering::Relaxed) || arrivals.is_empty() {
            return;
        }
        let frac = done.len() as f64 / expect as f64;
        if frac < SPECULATE_ROUND_FRACTION {
            return;
        }
        let mut sorted = arrivals.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let threshold = (SPECULATE_MULTIPLIER * median).max(SPECULATE_MIN_SECS);
        if t_round.elapsed().as_secs_f64() <= threshold {
            return;
        }
        for (&block, job) in spare {
            if done.contains(&block) || !speculated.insert(block) {
                continue;
            }
            *copies.entry(block).or_insert(1) += 1;
            self.queue.push_retry(job.clone());
        }
    }

    /// Readiness barrier for one registered job: one ping per worker,
    /// wait for all pongs. Absorbs worker startup cost (thread spawn +
    /// backend build — PJRT client construction and artifact
    /// compilation) so subsequent rounds time only steady-state work.
    /// Returns the barrier's wall seconds.
    pub fn warmup(&self, job: JobId) -> Result<f64> {
        let t0 = std::time::Instant::now();
        for w in 0..self.workers {
            self.queue.push_to_worker(
                w,
                Job {
                    job,
                    block: usize::MAX,
                    round: 0,
                    payload: JobPayload::Ping,
                },
            );
        }
        for _ in 0..self.workers {
            match self.results.recv_timeout(WARMUP_TIMEOUT) {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e.error),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(anyhow!(
                        "warmup stalled: no pong for {}s (worker startup hung)",
                        WARMUP_TIMEOUT.as_secs()
                    ))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(self.hangup_error("during warmup"))
                }
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// High water of simultaneously registered (open) jobs over the
    /// pool's lifetime.
    pub fn max_open_jobs(&self) -> usize {
        self.open_high_water.load(Ordering::Relaxed)
    }

    /// High water of distinct jobs simultaneously queued in the shared
    /// (dynamic) queue.
    pub fn max_jobs_interleaved(&self) -> usize {
        self.queue.max_jobs_interleaved()
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The loud, context-rich error a silent worker escalates to.
fn stall_error(s: &Stall) -> JobError {
    JobError {
        job: s.job,
        block: s.block,
        error: anyhow!(
            "round {} stalled: block {} on worker {}, no heartbeat for {}ms",
            s.round,
            s.block,
            s.worker,
            s.silent.as_millis()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};
    use crate::coordinator::messages::{JobResult, SOLO_JOB};
    use crate::coordinator::worker::BlockSource;
    use crate::image::SyntheticOrtho;
    use crate::kmeans::math;
    use crate::resilience::{FaultKind, FaultPlan};
    use crate::runtime::BackendSpec;

    fn context(fault: Option<FaultPlan>) -> (Arc<WorkerContext>, Arc<crate::image::Raster>) {
        let img = Arc::new(SyntheticOrtho::default().with_seed(11).generate(48, 40));
        let plan = Arc::new(BlockPlan::new(48, 40, BlockShape::Square { side: 16 }));
        let ctx = Arc::new(WorkerContext {
            plan,
            source: BlockSource::Direct(Arc::clone(&img)),
            backend: BackendSpec::Native {
                k: 2,
                channels: 3,
                local_iters: 4,
            },
            fault,
            local_mode: false,
            exec: crate::plan::ExecPlan::default().with_arena_mb(0),
            content: SOLO_JOB,
        });
        (ctx, img)
    }

    fn step_jobs(id: JobId, n: usize, centroids: &Arc<Vec<f32>>) -> Vec<Job> {
        (0..n)
            .map(|b| Job {
                job: id,
                block: b,
                round: 1,
                payload: JobPayload::Step {
                    centroids: Arc::clone(centroids),
                    drift: None,
                },
            })
            .collect()
    }

    #[test]
    fn round_results_cover_all_blocks_sorted() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(3, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        assert_eq!(outcomes.len(), nblocks);
        let blocks: Vec<usize> = outcomes.iter().map(|o| o.block).collect();
        assert_eq!(blocks, (0..nblocks).collect::<Vec<_>>());
        assert!(outcomes.iter().all(|o| o.job == SOLO_JOB));
        pool.shutdown();
    }

    #[test]
    fn parallel_reduction_equals_whole_image_step() {
        let (ctx, img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(4, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen_v = vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0];
        let cen = Arc::new(cen_v.clone());
        let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        let mut merged = math::StepAccum::zeros(2, 3);
        for o in &outcomes {
            match &o.result {
                JobResult::Step { accum } => merged.merge(accum),
                _ => unreachable!(),
            }
        }
        let whole = math::step(img.as_pixels(), &cen_v, 2, 3);
        assert_eq!(merged.counts, whole.counts);
        for (a, b) in merged.sums.iter().zip(&whole.sums) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert!((merged.inertia - whole.inertia).abs() < 1e-3);
        pool.shutdown();
    }

    #[test]
    fn injected_failure_propagates() {
        let (ctx, _img) = context(Some(FaultPlan::always(2, FaultKind::Error)));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn single_failure_recovers_under_retry_budget() {
        // Block 2 errors exactly once; with one retry the round must
        // complete with every block present, and the merged reduction
        // must equal a clean round's (the retry recomputes from the
        // same centroids — bit-identical).
        let fault = FaultPlan::new(2, FaultKind::Error, 1);
        let (ctx, _img) = context(Some(fault.clone()));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 1)
            .unwrap();
        assert_eq!(outcomes.len(), nblocks);
        assert!(fault.trips() >= 2, "block 2 must have been revisited");
        let blocks: Vec<usize> = outcomes.iter().map(|o| o.block).collect();
        assert_eq!(blocks, (0..nblocks).collect::<Vec<_>>());

        let (clean_ctx, _img) = context(None);
        let clean_pool = WorkerPool::spawn(2, Schedule::Dynamic);
        clean_pool.register_job(SOLO_JOB, clean_ctx);
        let clean = clean_pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        for (a, b) in outcomes.iter().zip(&clean) {
            match (&a.result, &b.result) {
                (JobResult::Step { accum: x }, JobResult::Step { accum: y }) => {
                    assert_eq!(x.counts, y.counts);
                    assert_eq!(x.sums, y.sums, "retried block diverged");
                    assert_eq!(x.inertia.to_bits(), y.inertia.to_bits());
                }
                other => unreachable!("{other:?}"),
            }
        }
        pool.shutdown();
        clean_pool.shutdown();
    }

    #[test]
    fn retry_budget_exhaustion_fails_with_attempt_context() {
        let (ctx, _img) = context(Some(FaultPlan::always(1, FaultKind::Error)));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 2)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "{msg}");
        assert!(msg.contains("3 attempts") && msg.contains("retry budget 2"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn injected_panic_is_caught_reported_and_retried() {
        // The panic is converted to a JobError carrying the panic
        // message; with a retry budget the round still completes, and
        // the pool stays serviceable for later rounds (capacity must
        // not decay).
        let fault = FaultPlan::new(0, FaultKind::Panic, 1);
        let (ctx, _img) = context(Some(fault));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Static);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 1)
            .unwrap();
        assert_eq!(outcomes.len(), nblocks);
        // Pool still fully functional after the panic.
        for _ in 0..2 {
            let again = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
            assert_eq!(again.len(), nblocks);
        }
        pool.shutdown();
    }

    #[test]
    fn injected_panic_without_retries_surfaces_the_message() {
        let (ctx, _img) = context(Some(FaultPlan::always(1, FaultKind::Panic)));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked") && msg.contains("injected panic"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn reader_io_fault_recovers_like_any_error() {
        let fault = FaultPlan::new(3, FaultKind::ReaderIo, 1);
        let (ctx, _img) = context(Some(fault));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(3, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 1)
            .unwrap();
        assert_eq!(outcomes.len(), nblocks);
        pool.shutdown();
    }

    #[test]
    fn unregistered_job_fails_cleanly() {
        let (ctx, _img) = context(None);
        let pool = WorkerPool::spawn(1, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool.run_round(step_jobs(99, 1, &cen)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no registered context"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn two_jobs_share_one_pool() {
        let (ctx_a, img) = context(None);
        // second job: same image, different k
        let ctx_b = Arc::new(WorkerContext {
            backend: BackendSpec::Native {
                k: 3,
                channels: 3,
                local_iters: 4,
            },
            ..(*ctx_a).clone()
        });
        let nblocks = ctx_a.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(1, ctx_a);
        pool.register_job(2, ctx_b);
        assert_eq!(pool.max_open_jobs(), 2);
        let cen2 = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let cen3 = Arc::new(vec![10.0, 10.0, 10.0, 120.0, 120.0, 120.0, 220.0, 220.0, 220.0]);
        let mut jobs = step_jobs(1, nblocks, &cen2);
        jobs.extend(step_jobs(2, nblocks, &cen3));
        pool.submit(jobs);
        let mut merged_a = math::StepAccum::zeros(2, 3);
        let mut merged_b = math::StepAccum::zeros(3, 3);
        for _ in 0..2 * nblocks {
            let o = pool.recv_result().unwrap().unwrap();
            match (&o.result, o.job) {
                (JobResult::Step { accum }, 1) => merged_a.merge(accum),
                (JobResult::Step { accum }, 2) => merged_b.merge(accum),
                other => unreachable!("{other:?}"),
            }
        }
        let whole_a = math::step(img.as_pixels(), &cen2, 2, 3);
        let whole_b = math::step(img.as_pixels(), &cen3, 3, 3);
        assert_eq!(merged_a.counts, whole_a.counts);
        assert_eq!(merged_b.counts, whole_b.counts);
        pool.retire_job(1);
        pool.retire_job(2);
        pool.shutdown();
    }

    #[test]
    fn multiple_rounds_reuse_workers() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Static);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0, 0.0, 0.0, 255.0, 255.0, 255.0]);
        for round in 0..3 {
            let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
            assert_eq!(outcomes.len(), nblocks, "round {round}");
        }
        pool.shutdown();
    }

    #[test]
    fn static_schedule_uses_all_workers() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        assert!(nblocks >= 4);
        let pool = WorkerPool::spawn(2, Schedule::Static);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        let w0 = outcomes.iter().filter(|o| o.worker == 0).count();
        let w1 = outcomes.iter().filter(|o| o.worker == 1).count();
        assert_eq!(w0 + w1, nblocks);
        assert!(w0 > 0 && w1 > 0, "static split degenerate: {w0}/{w1}");
        pool.shutdown();
    }

    #[test]
    fn empty_round_is_noop() {
        let (ctx, _img) = context(None);
        let pool = WorkerPool::spawn(1, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        assert!(pool.run_round(Vec::new()).unwrap().is_empty());
        pool.shutdown();
    }

    #[test]
    fn warmup_pings_all_workers() {
        let (ctx, _img) = context(None);
        let pool = WorkerPool::spawn(3, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let secs = pool.warmup(SOLO_JOB).unwrap();
        assert!(secs >= 0.0);
        pool.shutdown();
    }

    #[test]
    fn hung_block_is_escalated_and_stays_bit_identical() {
        // Block 2's first visit parks for a nominal 60s — without the
        // watchdog the round barrier would wait that long. With a
        // 50ms heartbeat timeout the leader escalates the silent
        // worker, a clone recomputes the block elsewhere, and the
        // round completes promptly with values identical to a clean
        // run (the parked original's late duplicate is discarded).
        let fault = FaultPlan::new(2, FaultKind::Hang { ms: 60_000 }, 1);
        let (ctx, _img) = context(Some(fault.clone()));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.set_heartbeat_timeout_ms(50);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let t0 = Instant::now();
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 1)
            .unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "watchdog must bound the round, not the 60s park"
        );
        assert_eq!(outcomes.len(), nblocks);
        assert!(fault.trips() >= 2, "block 2 must have been re-visited");

        let (clean_ctx, _img) = context(None);
        let clean_pool = WorkerPool::spawn(2, Schedule::Dynamic);
        clean_pool.register_job(SOLO_JOB, clean_ctx);
        let clean = clean_pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        for (a, b) in outcomes.iter().zip(&clean) {
            match (&a.result, &b.result) {
                (JobResult::Step { accum: x }, JobResult::Step { accum: y }) => {
                    assert_eq!(x.counts, y.counts);
                    assert_eq!(x.sums, y.sums, "escalated block diverged");
                    assert_eq!(x.inertia.to_bits(), y.inertia.to_bits());
                }
                other => unreachable!("{other:?}"),
            }
        }
        fault.release(); // wake the parked worker so shutdown can join
        pool.shutdown();
        clean_pool.shutdown();
    }

    #[test]
    fn stall_with_exhausted_budget_errors_loudly() {
        let fault = FaultPlan::new(1, FaultKind::Hang { ms: 60_000 }, 1);
        let (ctx, _img) = context(Some(fault.clone()));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.set_heartbeat_timeout_ms(50);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 0)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stalled: block 1 on worker"), "{msg}");
        assert!(msg.contains("no heartbeat for"), "{msg}");
        assert!(msg.contains("retry budget 0 exhausted"), "{msg}");
        fault.release();
        pool.shutdown();
    }

    #[test]
    fn speculation_rescues_a_straggler_without_the_watchdog() {
        // Watchdog off (timeout 0), speculation on: the straggling
        // block is cloned once ≥75% of the round has completed and the
        // round has overrun the median block time, without consuming
        // any retry budget (retries = 0 here).
        let fault = FaultPlan::new(2, FaultKind::Hang { ms: 60_000 }, 1);
        let (ctx, _img) = context(Some(fault.clone()));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.set_heartbeat_timeout_ms(0);
        pool.set_speculate(true);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let t0 = Instant::now();
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 0)
            .unwrap();
        assert!(t0.elapsed() < Duration::from_secs(30), "speculation must fire");
        assert_eq!(outcomes.len(), nblocks);
        assert!(fault.trips() >= 2, "the straggler must have been cloned");
        fault.release();
        pool.shutdown();
    }
}
