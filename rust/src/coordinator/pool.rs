//! The leader's handle on its SPMD worker pool.
//!
//! A pool is spawned **once** and can serve many clustering jobs over
//! its lifetime: jobs register a [`WorkerContext`] under their
//! [`JobId`], submit tagged block jobs, receive tagged outcomes, and
//! retire when done (dropping worker-side cached state). Single-run
//! callers use the [`WorkerPool::run_round`] barrier, which keeps the
//! paper's per-iteration synchronous semantics; the service layer uses
//! the streaming [`WorkerPool::submit`]/[`WorkerPool::recv_result`]
//! pair to interleave rounds of many jobs at once.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::messages::{Job, JobError, JobId, JobOutcome, JobPayload};
use super::queue::{JobQueue, Schedule};
use super::worker::{panic_message, worker_main, ContextRegistry, WorkerContext};

/// A pool of worker threads processing tagged block jobs.
pub struct WorkerPool {
    queue: Arc<JobQueue>,
    registry: Arc<ContextRegistry>,
    results: Receiver<Result<JobOutcome, JobError>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    /// High water of simultaneously registered jobs (instrumentation
    /// backing the admission-cap assertions).
    open_high_water: AtomicUsize,
    /// The last panic that escaped a worker loop (the supervisor
    /// records it before respawning). When the pool hangs up, this is
    /// the root cause the leader forwards instead of a bare
    /// "worker pool hung up".
    last_panic: Arc<Mutex<Option<String>>>,
}

impl WorkerPool {
    /// Spawn `workers` threads. Workers build per-job compute backends
    /// lazily from the registered contexts (PJRT clients are per-worker
    /// by necessity — and by design: it is the parpool model).
    ///
    /// Each thread runs a **supervisor loop**: `worker_main` already
    /// converts per-block panics into [`JobError`]s, but if a panic
    /// ever escapes the loop itself (a bug outside block dispatch),
    /// the supervisor records the message and re-enters `worker_main`
    /// with fresh worker-local state — the pool's capacity never
    /// decays to zero behind the leader's back.
    pub fn spawn(workers: usize, schedule: Schedule) -> WorkerPool {
        assert!(workers > 0, "need at least one worker");
        let queue = Arc::new(JobQueue::new(workers, schedule));
        let registry = Arc::new(ContextRegistry::new());
        let last_panic: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let (tx, rx) = channel();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let queue = Arc::clone(&queue);
            let registry = Arc::clone(&registry);
            let last_panic = Arc::clone(&last_panic);
            let tx = tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("blockms-worker-{w}"))
                    .spawn(move || loop {
                        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || worker_main(w, Arc::clone(&registry), Arc::clone(&queue), tx.clone()),
                        ));
                        match caught {
                            // Clean exit: queue closed or leader gone.
                            Ok(()) => break,
                            Err(payload) => {
                                let msg = panic_message(payload.as_ref());
                                *last_panic.lock().unwrap() =
                                    Some(format!("worker {w} panicked: {msg}"));
                                // Respawn: re-enter the loop with fresh
                                // worker-local state (engines, bounds,
                                // tiles all rebuild lazily).
                            }
                        }
                    })
                    .expect("spawn worker thread"),
            );
        }
        WorkerPool {
            queue,
            registry,
            results: rx,
            handles,
            workers,
            open_high_water: AtomicUsize::new(0),
            last_panic,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Register the per-job context workers will resolve `job`'s blocks
    /// against. Must happen before any of the job's blocks are
    /// submitted.
    pub fn register_job(&self, job: JobId, ctx: Arc<WorkerContext>) {
        let open = self.registry.register(job, ctx);
        self.open_high_water.fetch_max(open, Ordering::Relaxed);
    }

    /// Drop the job's registered context and tell every worker to shed
    /// its cached per-job state (backend, reader, pruned bounds). Call
    /// only after all of the job's in-flight outcomes have been
    /// received — a retire overtaking live blocks would fail them.
    ///
    /// Unshared jobs own their decoded arena tiles (content id == job
    /// id), so the plain retire purges them too. Share-group members
    /// must use [`WorkerPool::retire_job_with`] so only the group's
    /// last survivor evicts the shared content.
    pub fn retire_job(&self, job: JobId) {
        self.retire_job_with(job, Some(job));
    }

    /// [`WorkerPool::retire_job`] with explicit control over which
    /// arena *content* (if any) workers purge alongside the job's
    /// private state. `None` keeps shared tiles resident for the
    /// job's still-running share-group siblings.
    pub fn retire_job_with(&self, job: JobId, purge_content: Option<u64>) {
        self.registry.remove(job);
        self.queue.drop_job_group(job);
        for w in 0..self.workers {
            self.queue.push_to_worker(
                w,
                Job {
                    job,
                    block: usize::MAX,
                    round: 0,
                    payload: JobPayload::Retire { purge_content },
                },
            );
        }
    }

    /// Tag `job` as a member of rotation share group `group` (see
    /// [`JobQueue::set_job_group`]). Call alongside
    /// [`WorkerPool::register_job`], before the job's first submit.
    pub fn set_job_group(&self, job: JobId, group: u64) {
        self.queue.set_job_group(job, group);
    }

    /// Remove the job's queued (not yet popped) blocks; returns how many
    /// were removed so the leader can shrink its expected-outcome count.
    pub fn purge_job(&self, job: JobId) -> usize {
        self.queue.purge_job(job)
    }

    /// Enqueue tagged jobs without waiting for their outcomes (the
    /// service's streaming mode).
    pub fn submit(&self, jobs: Vec<Job>) {
        if !jobs.is_empty() {
            self.queue.push_round(jobs);
        }
    }

    /// The root cause of a pool hangup, if one was recorded: the last
    /// panic message that escaped a worker loop.
    pub fn hangup_cause(&self) -> Option<String> {
        self.last_panic.lock().unwrap().clone()
    }

    /// Build the pool-hangup error, attaching the recorded root cause
    /// (the actual worker panic) when there is one — a bare
    /// "worker pool hung up" is undiagnosable in a server log.
    fn hangup_error(&self, when: &str) -> anyhow::Error {
        match self.hangup_cause() {
            Some(cause) => anyhow!("worker pool hung up {when}: {cause}"),
            None => anyhow!("worker pool hung up {when}"),
        }
    }

    /// Receive the next outcome (any job). The outer `Err` means the
    /// pool itself hung up (all workers gone); the inner [`JobError`]
    /// is a per-job failure that leaves the pool serviceable.
    pub fn recv_result(&self) -> Result<Result<JobOutcome, JobError>> {
        self.results
            .recv()
            .map_err(|_| self.hangup_error("between results"))
    }

    /// Execute one round of jobs, blocking until all results arrive.
    /// Outcomes are returned sorted by block index (deterministic
    /// downstream reduction regardless of completion order). The first
    /// worker error aborts the round. Assumes the caller is the only
    /// one with jobs in flight — multi-job leaders use
    /// [`WorkerPool::submit`] / [`WorkerPool::recv_result`] instead.
    pub fn run_round(&self, jobs: Vec<Job>) -> Result<Vec<JobOutcome>> {
        self.run_round_resilient(jobs, 0)
    }

    /// [`WorkerPool::run_round`] with a per-block retry budget. A
    /// failed block (worker error or caught panic) is re-enqueued up
    /// to `retries` times — via [`JobQueue::push_retry`], so placement
    /// follows the schedule — before the round aborts with the final
    /// error. The retried attempt recomputes from the same shipped
    /// centroids and the failing worker has already evicted its state
    /// for that `(job, block)`, so a recovered round is bit-identical
    /// to one that never failed (see [`crate::resilience`]).
    pub fn run_round_resilient(&self, jobs: Vec<Job>, retries: usize) -> Result<Vec<JobOutcome>> {
        let expect = jobs.len();
        if expect == 0 {
            return Ok(Vec::new());
        }
        // Keep a clone of each block's job for re-enqueue (cheap: the
        // payload's centroids/drift are behind `Arc`s).
        let spare: HashMap<usize, Job> = if retries > 0 {
            jobs.iter().map(|j| (j.block, j.clone())).collect()
        } else {
            HashMap::new()
        };
        let mut attempts: HashMap<usize, usize> = HashMap::new();
        self.queue.push_round(jobs);
        let mut out = Vec::with_capacity(expect);
        while out.len() < expect {
            match self.results.recv() {
                Ok(Ok(outcome)) => out.push(outcome),
                // Worker errors carry their own worker/block attribution.
                Ok(Err(e)) => {
                    let used = attempts.entry(e.block).or_insert(0);
                    if *used < retries {
                        *used += 1;
                        let job = spare
                            .get(&e.block)
                            .cloned()
                            .expect("spares kept whenever retries > 0");
                        self.queue.push_retry(job);
                    } else if retries == 0 {
                        return Err(e.error);
                    } else {
                        return Err(e.error.context(format!(
                            "block {} failed {} attempts (retry budget {retries})",
                            e.block,
                            *used + 1
                        )));
                    }
                }
                Err(_) => {
                    return Err(self
                        .hangup_error(&format!("mid-round ({}/{expect} results)", out.len())))
                }
            }
        }
        out.sort_by_key(|o| o.block);
        Ok(out)
    }

    /// Readiness barrier for one registered job: one ping per worker,
    /// wait for all pongs. Absorbs worker startup cost (thread spawn +
    /// backend build — PJRT client construction and artifact
    /// compilation) so subsequent rounds time only steady-state work.
    /// Returns the barrier's wall seconds.
    pub fn warmup(&self, job: JobId) -> Result<f64> {
        let t0 = std::time::Instant::now();
        for w in 0..self.workers {
            self.queue.push_to_worker(
                w,
                Job {
                    job,
                    block: usize::MAX,
                    round: 0,
                    payload: JobPayload::Ping,
                },
            );
        }
        for _ in 0..self.workers {
            match self.results.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e.error),
                Err(_) => return Err(anyhow!("worker pool hung up during warmup")),
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// High water of simultaneously registered (open) jobs over the
    /// pool's lifetime.
    pub fn max_open_jobs(&self) -> usize {
        self.open_high_water.load(Ordering::Relaxed)
    }

    /// High water of distinct jobs simultaneously queued in the shared
    /// (dynamic) queue.
    pub fn max_jobs_interleaved(&self) -> usize {
        self.queue.max_jobs_interleaved()
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};
    use crate::coordinator::messages::{JobResult, SOLO_JOB};
    use crate::coordinator::worker::BlockSource;
    use crate::image::SyntheticOrtho;
    use crate::kmeans::math;
    use crate::resilience::{FaultKind, FaultPlan};
    use crate::runtime::BackendSpec;

    fn context(fault: Option<FaultPlan>) -> (Arc<WorkerContext>, Arc<crate::image::Raster>) {
        let img = Arc::new(SyntheticOrtho::default().with_seed(11).generate(48, 40));
        let plan = Arc::new(BlockPlan::new(48, 40, BlockShape::Square { side: 16 }));
        let ctx = Arc::new(WorkerContext {
            plan,
            source: BlockSource::Direct(Arc::clone(&img)),
            backend: BackendSpec::Native {
                k: 2,
                channels: 3,
                local_iters: 4,
            },
            fault,
            local_mode: false,
            exec: crate::plan::ExecPlan::default().with_arena_mb(0),
            content: SOLO_JOB,
        });
        (ctx, img)
    }

    fn step_jobs(id: JobId, n: usize, centroids: &Arc<Vec<f32>>) -> Vec<Job> {
        (0..n)
            .map(|b| Job {
                job: id,
                block: b,
                round: 1,
                payload: JobPayload::Step {
                    centroids: Arc::clone(centroids),
                    drift: None,
                },
            })
            .collect()
    }

    #[test]
    fn round_results_cover_all_blocks_sorted() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(3, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        assert_eq!(outcomes.len(), nblocks);
        let blocks: Vec<usize> = outcomes.iter().map(|o| o.block).collect();
        assert_eq!(blocks, (0..nblocks).collect::<Vec<_>>());
        assert!(outcomes.iter().all(|o| o.job == SOLO_JOB));
        pool.shutdown();
    }

    #[test]
    fn parallel_reduction_equals_whole_image_step() {
        let (ctx, img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(4, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen_v = vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0];
        let cen = Arc::new(cen_v.clone());
        let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        let mut merged = math::StepAccum::zeros(2, 3);
        for o in &outcomes {
            match &o.result {
                JobResult::Step { accum } => merged.merge(accum),
                _ => unreachable!(),
            }
        }
        let whole = math::step(img.as_pixels(), &cen_v, 2, 3);
        assert_eq!(merged.counts, whole.counts);
        for (a, b) in merged.sums.iter().zip(&whole.sums) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert!((merged.inertia - whole.inertia).abs() < 1e-3);
        pool.shutdown();
    }

    #[test]
    fn injected_failure_propagates() {
        let (ctx, _img) = context(Some(FaultPlan::always(2, FaultKind::Error)));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn single_failure_recovers_under_retry_budget() {
        // Block 2 errors exactly once; with one retry the round must
        // complete with every block present, and the merged reduction
        // must equal a clean round's (the retry recomputes from the
        // same centroids — bit-identical).
        let fault = FaultPlan::new(2, FaultKind::Error, 1);
        let (ctx, _img) = context(Some(fault.clone()));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 1)
            .unwrap();
        assert_eq!(outcomes.len(), nblocks);
        assert!(fault.trips() >= 2, "block 2 must have been revisited");
        let blocks: Vec<usize> = outcomes.iter().map(|o| o.block).collect();
        assert_eq!(blocks, (0..nblocks).collect::<Vec<_>>());

        let (clean_ctx, _img) = context(None);
        let clean_pool = WorkerPool::spawn(2, Schedule::Dynamic);
        clean_pool.register_job(SOLO_JOB, clean_ctx);
        let clean = clean_pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        for (a, b) in outcomes.iter().zip(&clean) {
            match (&a.result, &b.result) {
                (JobResult::Step { accum: x }, JobResult::Step { accum: y }) => {
                    assert_eq!(x.counts, y.counts);
                    assert_eq!(x.sums, y.sums, "retried block diverged");
                    assert_eq!(x.inertia.to_bits(), y.inertia.to_bits());
                }
                other => unreachable!("{other:?}"),
            }
        }
        pool.shutdown();
        clean_pool.shutdown();
    }

    #[test]
    fn retry_budget_exhaustion_fails_with_attempt_context() {
        let (ctx, _img) = context(Some(FaultPlan::always(1, FaultKind::Error)));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 2)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "{msg}");
        assert!(msg.contains("3 attempts") && msg.contains("retry budget 2"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn injected_panic_is_caught_reported_and_retried() {
        // The panic is converted to a JobError carrying the panic
        // message; with a retry budget the round still completes, and
        // the pool stays serviceable for later rounds (capacity must
        // not decay).
        let fault = FaultPlan::new(0, FaultKind::Panic, 1);
        let (ctx, _img) = context(Some(fault));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Static);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 1)
            .unwrap();
        assert_eq!(outcomes.len(), nblocks);
        // Pool still fully functional after the panic.
        for _ in 0..2 {
            let again = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
            assert_eq!(again.len(), nblocks);
        }
        pool.shutdown();
    }

    #[test]
    fn injected_panic_without_retries_surfaces_the_message() {
        let (ctx, _img) = context(Some(FaultPlan::always(1, FaultKind::Panic)));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked") && msg.contains("injected panic"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn reader_io_fault_recovers_like_any_error() {
        let fault = FaultPlan::new(3, FaultKind::ReaderIo, 1);
        let (ctx, _img) = context(Some(fault));
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(3, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let outcomes = pool
            .run_round_resilient(step_jobs(SOLO_JOB, nblocks, &cen), 1)
            .unwrap();
        assert_eq!(outcomes.len(), nblocks);
        pool.shutdown();
    }

    #[test]
    fn unregistered_job_fails_cleanly() {
        let (ctx, _img) = context(None);
        let pool = WorkerPool::spawn(1, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let err = pool.run_round(step_jobs(99, 1, &cen)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no registered context"), "{msg}");
        pool.shutdown();
    }

    #[test]
    fn two_jobs_share_one_pool() {
        let (ctx_a, img) = context(None);
        // second job: same image, different k
        let ctx_b = Arc::new(WorkerContext {
            backend: BackendSpec::Native {
                k: 3,
                channels: 3,
                local_iters: 4,
            },
            ..(*ctx_a).clone()
        });
        let nblocks = ctx_a.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Dynamic);
        pool.register_job(1, ctx_a);
        pool.register_job(2, ctx_b);
        assert_eq!(pool.max_open_jobs(), 2);
        let cen2 = Arc::new(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]);
        let cen3 = Arc::new(vec![10.0, 10.0, 10.0, 120.0, 120.0, 120.0, 220.0, 220.0, 220.0]);
        let mut jobs = step_jobs(1, nblocks, &cen2);
        jobs.extend(step_jobs(2, nblocks, &cen3));
        pool.submit(jobs);
        let mut merged_a = math::StepAccum::zeros(2, 3);
        let mut merged_b = math::StepAccum::zeros(3, 3);
        for _ in 0..2 * nblocks {
            let o = pool.recv_result().unwrap().unwrap();
            match (&o.result, o.job) {
                (JobResult::Step { accum }, 1) => merged_a.merge(accum),
                (JobResult::Step { accum }, 2) => merged_b.merge(accum),
                other => unreachable!("{other:?}"),
            }
        }
        let whole_a = math::step(img.as_pixels(), &cen2, 2, 3);
        let whole_b = math::step(img.as_pixels(), &cen3, 3, 3);
        assert_eq!(merged_a.counts, whole_a.counts);
        assert_eq!(merged_b.counts, whole_b.counts);
        pool.retire_job(1);
        pool.retire_job(2);
        pool.shutdown();
    }

    #[test]
    fn multiple_rounds_reuse_workers() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        let pool = WorkerPool::spawn(2, Schedule::Static);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0, 0.0, 0.0, 255.0, 255.0, 255.0]);
        for round in 0..3 {
            let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
            assert_eq!(outcomes.len(), nblocks, "round {round}");
        }
        pool.shutdown();
    }

    #[test]
    fn static_schedule_uses_all_workers() {
        let (ctx, _img) = context(None);
        let nblocks = ctx.plan.len();
        assert!(nblocks >= 4);
        let pool = WorkerPool::spawn(2, Schedule::Static);
        pool.register_job(SOLO_JOB, ctx);
        let cen = Arc::new(vec![0.0; 6]);
        let outcomes = pool.run_round(step_jobs(SOLO_JOB, nblocks, &cen)).unwrap();
        let w0 = outcomes.iter().filter(|o| o.worker == 0).count();
        let w1 = outcomes.iter().filter(|o| o.worker == 1).count();
        assert_eq!(w0 + w1, nblocks);
        assert!(w0 > 0 && w1 > 0, "static split degenerate: {w0}/{w1}");
        pool.shutdown();
    }

    #[test]
    fn empty_round_is_noop() {
        let (ctx, _img) = context(None);
        let pool = WorkerPool::spawn(1, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        assert!(pool.run_round(Vec::new()).unwrap().is_empty());
        pool.shutdown();
    }

    #[test]
    fn warmup_pings_all_workers() {
        let (ctx, _img) = context(None);
        let pool = WorkerPool::spawn(3, Schedule::Dynamic);
        pool.register_job(SOLO_JOB, ctx);
        let secs = pool.warmup(SOLO_JOB).unwrap();
        assert!(secs >= 0.0);
        pool.shutdown();
    }
}
