//! The coordinator: leader/worker parallel block processing for K-Means.
//!
//! [`Coordinator`] is the single-run public entry point. Configured with
//! a worker count, compute engine, I/O mode and clustering mode, it
//! executes the paper's pipeline over a [`BlockPlan`]:
//!
//! ```text
//!   image ──▶ block plan ──▶ job rounds ──▶ workers (N threads,
//!     each: read block → AOT kernel / native math) ──▶ leader reduce
//!     ──▶ centroid update ──▶ … ──▶ assign ──▶ reassembled label map
//! ```
//!
//! Modes: [`ClusterMode::Global`] (exactly-sequential-equivalent K-Means
//! with per-iteration reduction) and [`ClusterMode::Local`] (independent
//! per-block clustering + centroid harmonization — `blockproc(@kmeans)`).
//!
//! Internally each run is a [`RunMachine`]: an incremental per-job
//! reduction state machine ([`GlobalState`] / [`LocalState`]) driven
//! round by round over a [`WorkerPool`]. A `Coordinator` spins up a
//! private pool and drives one machine to completion; the persistent
//! multi-job [`crate::service`] layer drives many machines over one
//! shared pool, interleaving their blocks — both produce bit-identical
//! results because reduction order is block order either way.

mod global;
mod local;
mod messages;
mod pool;
mod queue;
mod worker;

pub use global::{GlobalOutput, GlobalPhase, GlobalState};
pub use local::{LocalOutput, LocalState};
pub use messages::{
    BlockTiming, Job, JobError, JobId, JobOutcome, JobPayload, JobResult, SOLO_JOB,
};
pub use pool::WorkerPool;
pub use queue::{JobQueue, Schedule};
pub use worker::{BlockSource, ContextRegistry, WorkerContext};

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::blocks::{BlockPlan, LabelMap};
use crate::image::{Raster, RasterSource};
use crate::kmeans::{InitMethod, KMeansConfig, SeqKMeans, StreamInit};
use crate::plan::ExecPlan;
use crate::resilience::{fnv1a, Checkpoint, FaultPlan};
use crate::runtime::BackendSpec;
use crate::shard::{spawn_shard_pool, ShardEndpoints, ShardSpec};
use crate::stripstore::{Backing, StripStore};

/// Which compute engine workers run.
#[derive(Clone, Debug, Default)]
pub enum Engine {
    /// Pure-rust math (no artifacts required).
    #[default]
    Native,
    /// AOT JAX/Pallas kernels via PJRT. `None` = auto-locate `artifacts/`.
    Pjrt { artifacts_dir: Option<PathBuf> },
}

impl Engine {
    /// The per-worker backend recipe for this engine at a given
    /// clustering width (shared by the solo [`Coordinator`] and the
    /// service's per-job contexts).
    pub fn backend_spec(&self, k: usize, channels: usize) -> Result<BackendSpec> {
        Ok(match self {
            Engine::Native => BackendSpec::Native {
                k,
                channels,
                local_iters: 8,
            },
            Engine::Pjrt { artifacts_dir } => {
                let dir = match artifacts_dir {
                    Some(d) => d.clone(),
                    None => crate::runtime::find_artifacts_dir().context(
                        "artifacts directory not found (run `make artifacts` or set BLOCKMS_ARTIFACTS)",
                    )?,
                };
                BackendSpec::Pjrt {
                    artifacts_dir: dir,
                    k,
                }
            }
        })
    }
}

/// How workers obtain block pixels.
#[derive(Clone, Debug, Default)]
pub enum IoMode {
    /// Crop from the shared in-memory raster (no I/O modelling).
    #[default]
    Direct,
    /// Through a strip store ( `blockproc` semantics, counted accesses).
    Strips {
        strip_rows: usize,
        /// Back the strips with a real file (true) or memory (false).
        file_backed: bool,
    },
}

/// Global vs per-block clustering.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClusterMode {
    #[default]
    Global,
    Local,
}

impl std::str::FromStr for ClusterMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "global" => Ok(ClusterMode::Global),
            "local" => Ok(ClusterMode::Local),
            other => Err(format!("unknown mode {other:?} (want global|local)")),
        }
    }
}

/// Clustering parameters (thin wrapper over [`KMeansConfig`] plus the
/// fixed-iteration option benches use for exact serial/parallel work
/// mirroring).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub k: usize,
    pub max_iters: usize,
    pub tol: f32,
    pub init: InitMethod,
    pub seed: u64,
    /// When set, run exactly this many Lloyd iterations (no convergence
    /// test) — both serial and parallel sides then do identical work.
    pub fixed_iters: Option<usize>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        let km = KMeansConfig::default();
        ClusterConfig {
            k: km.k,
            max_iters: km.max_iters,
            tol: km.tol,
            init: km.init,
            seed: km.seed,
            fixed_iters: None,
        }
    }
}

impl ClusterConfig {
    pub fn kmeans(&self) -> KMeansConfig {
        KMeansConfig {
            k: self.k,
            max_iters: self.max_iters,
            tol: self.tol,
            init: self.init.clone(),
            seed: self.seed,
        }
    }
}

/// Coordinator configuration: the resolved [`ExecPlan`] (block shape,
/// worker count, kernel, layout, arena, prefetch, strip cache) plus the
/// run-environment choices the planner does not select among (engine,
/// clustering mode, I/O model, schedule).
///
/// There is deliberately no kernel/layout/cache field here any more —
/// entry points resolve everything into `exec` up front (usually via
/// [`crate::plan::Planner::resolve`]) and the coordinator consumes only
/// that. Prefetch caveat: mispredicted read-aheads show up in the I/O
/// counters, so closed-form `AccessStats` assertions only hold with
/// `exec.prefetch` off. Pruned kernels keep per-(job, block) state on
/// the workers, so [`Schedule::Static`] keeps it warmest.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorConfig {
    /// The resolved execution plan this run follows (including the
    /// fault-tolerance knobs: [`ExecPlan::retries`] bounds per-block
    /// re-queues, [`ExecPlan::checkpoint_every`] sets the round cadence
    /// of checkpoint writes).
    pub exec: ExecPlan,
    pub engine: Engine,
    pub mode: ClusterMode,
    pub io: IoMode,
    pub schedule: Schedule,
    /// Deterministic fault injection (tests, the resilience bench, CI
    /// fault drills): which block fails, how, and on which visits.
    pub fault: Option<FaultPlan>,
    /// Where to write round-boundary checkpoints. Only consulted when
    /// `exec.checkpoint_every > 0`; global mode only (local mode is a
    /// single round — there is no boundary to checkpoint).
    pub checkpoint: Option<PathBuf>,
    /// Resume from this checkpoint instead of round 0. The file's
    /// fingerprint must match the current run's configuration; the
    /// resumed run is bit-identical to an uninterrupted one (see
    /// [`crate::resilience`]).
    pub resume: Option<PathBuf>,
}

/// Per-block cost attribution for one round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockCost {
    pub block: usize,
    pub worker: usize,
    pub io_secs: f64,
    pub compute_secs: f64,
    pub pixels: usize,
}

impl BlockCost {
    fn from_outcome(o: &JobOutcome) -> BlockCost {
        BlockCost {
            block: o.block,
            worker: o.worker,
            io_secs: o.timing.io_secs,
            compute_secs: o.timing.compute_secs,
            pixels: o.timing.pixels,
        }
    }

    pub fn total_secs(&self) -> f64 {
        self.io_secs + self.compute_secs
    }
}

/// What kind of round a record describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundKind {
    Step,
    Assign,
    Local,
}

/// Timing record for one round (one barrier-to-barrier phase).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub kind: RoundKind,
    pub wall_secs: f64,
    pub costs: Vec<BlockCost>,
}

/// Result of a coordinated clustering run.
#[derive(Clone, Debug)]
pub struct ClusterOutput {
    pub labels: Vec<u32>,
    pub centroids: Vec<f32>,
    pub inertia: f64,
    /// Inertia entering each Lloyd iteration (global mode; monotone
    /// non-increasing — a tested invariant). Empty in local mode.
    pub inertia_trace: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    /// Wall-clock seconds for the whole run (init → assembled labels).
    pub total_secs: f64,
    /// Worker startup seconds (thread spawn + backend build, absorbed by
    /// the warmup barrier) — the parpool-startup analogue, excluded from
    /// the paper-table replays. Zero for service jobs (the pool is
    /// already warm).
    pub spawn_secs: f64,
    /// Per-round timing breakdown (feeds the simtime replay).
    pub rounds: Vec<RoundRecord>,
    /// Strip-store access counters, when [`IoMode::Strips`] was used.
    pub io_stats: Option<crate::stripstore::AccessSnapshot>,
    pub blocks: usize,
    pub workers: usize,
}

impl ClusterOutput {
    /// Assemble from a finished [`RunMachine`] plus the run-level fields
    /// the machine cannot know (single construction point for the solo
    /// coordinator and the service, so the two cannot drift). The label
    /// map is materialized dense here — spooled maps (budgeted runs)
    /// read back; callers that must stay bounded use
    /// [`Coordinator::cluster_source`]'s [`StreamRun`] instead.
    pub fn from_machine(
        m: MachineOutput,
        total_secs: f64,
        spawn_secs: f64,
        io_stats: Option<AccessSnapshot>,
        blocks: usize,
        workers: usize,
    ) -> Result<ClusterOutput> {
        Ok(ClusterOutput {
            labels: m.labels.into_dense()?,
            centroids: m.centroids,
            inertia: m.inertia,
            inertia_trace: m.inertia_trace,
            iterations: m.iterations,
            converged: m.converged,
            total_secs,
            spawn_secs,
            rounds: m.rounds,
            io_stats,
            blocks,
            workers,
        })
    }
}

/// Result of an out-of-core [`Coordinator::cluster_source`] run: the
/// same clustering facts as [`ClusterOutput`], but labels stay behind
/// the [`LabelMap`] (possibly a disk spool) and the audited resident
/// high-water mark is reported.
#[derive(Debug)]
pub struct StreamRun {
    pub labels: LabelMap,
    pub centroids: Vec<f32>,
    pub inertia: f64,
    pub inertia_trace: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub total_secs: f64,
    pub spawn_secs: f64,
    pub rounds: Vec<RoundRecord>,
    /// Strip-store access counters (streaming always runs strip I/O).
    pub io_stats: AccessSnapshot,
    /// High-water mark of tracked resident pixel bytes — the audited
    /// side of the `mem_mb` contract (also in `io_stats`).
    pub peak_resident_bytes: u64,
    pub blocks: usize,
    pub workers: usize,
    pub height: usize,
    pub width: usize,
}

/// One clustering run's reduction state machine: global or local mode
/// behind one interface. Drive it with [`RunMachine::start_round`] →
/// absorb every outcome → [`RunMachine::finish_round`], until
/// [`RunMachine::done`]; reduction happens in block order regardless of
/// arrival order, which is what makes interleaved multi-job runs
/// bit-identical to solo runs.
pub enum RunMachine {
    Global(GlobalState),
    Local(LocalState),
}

/// Mode-independent view of a finished [`RunMachine`]. Labels are a
/// [`LabelMap`]: dense unless the machine was built with a label
/// budget, in which case they live in a disk spool.
#[derive(Debug)]
pub struct MachineOutput {
    pub labels: LabelMap,
    pub centroids: Vec<f32>,
    pub inertia: f64,
    pub inertia_trace: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub rounds: Vec<RoundRecord>,
}

impl RunMachine {
    /// Build the machine for a job: same init draw as the sequential
    /// baseline, mode picked from the config. `label_budget` sizes the
    /// final label sink (`None` = dense in memory, the seed behaviour).
    pub fn new(
        mode: ClusterMode,
        plan: Arc<BlockPlan>,
        channels: usize,
        ccfg: &ClusterConfig,
        init_centroids: Vec<f32>,
        label_budget: Option<u64>,
    ) -> RunMachine {
        match mode {
            ClusterMode::Global => RunMachine::Global(GlobalState::new(
                plan,
                channels,
                &ccfg.kmeans(),
                ccfg.fixed_iters,
                init_centroids,
                label_budget,
            )),
            ClusterMode::Local => RunMachine::Local(LocalState::new(
                plan,
                channels,
                ccfg.k,
                init_centroids,
                label_budget,
            )),
        }
    }

    pub fn start_round(&mut self, job: JobId) -> Vec<Job> {
        match self {
            RunMachine::Global(g) => g.start_round(job),
            RunMachine::Local(l) => l.start_round(job),
        }
    }

    /// Returns `true` when the in-flight round is complete.
    pub fn absorb(&mut self, outcome: JobOutcome) -> Result<bool> {
        match self {
            RunMachine::Global(g) => g.absorb(outcome),
            RunMachine::Local(l) => l.absorb(outcome),
        }
    }

    /// Whether the in-flight round still needs this outcome. Duplicate
    /// `(job, block, round)` copies — the losing twins of watchdog
    /// escalation and straggler speculation — answer `false` and must
    /// be discarded instead of absorbed.
    pub fn wants(&self, outcome: &JobOutcome) -> bool {
        match self {
            RunMachine::Global(g) => g.wants(outcome),
            RunMachine::Local(l) => l.wants(outcome),
        }
    }

    /// Whether `block` is still missing from the in-flight round (an
    /// error for a block a twin already delivered is not a failure).
    pub fn block_pending(&self, block: usize) -> bool {
        match self {
            RunMachine::Global(g) => g.block_pending(block),
            RunMachine::Local(l) => l.block_pending(block),
        }
    }

    pub fn finish_round(&mut self) -> Result<()> {
        match self {
            RunMachine::Global(g) => g.finish_round(),
            RunMachine::Local(l) => l.finish_round(),
        }
    }

    pub fn done(&self) -> bool {
        match self {
            RunMachine::Global(g) => g.done(),
            RunMachine::Local(l) => l.done(),
        }
    }

    /// Snapshot the round-boundary state, or `None` when this machine
    /// cannot be checkpointed (local mode is one round end to end).
    pub fn snapshot(&self, fingerprint: u64) -> Option<Checkpoint> {
        match self {
            RunMachine::Global(g) => Some(g.snapshot(fingerprint)),
            RunMachine::Local(_) => None,
        }
    }

    /// Mid-round-safe snapshot of the last completed boundary (see
    /// [`GlobalState::boundary_snapshot`]); `None` for local mode or a
    /// finished run.
    pub fn boundary_snapshot(&self, fingerprint: u64) -> Option<Checkpoint> {
        match self {
            RunMachine::Global(g) => g.boundary_snapshot(fingerprint),
            RunMachine::Local(_) => None,
        }
    }

    /// Rewind a freshly built machine to a checkpointed boundary.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        match self {
            RunMachine::Global(g) => g.restore(ck),
            RunMachine::Local(_) => {
                anyhow::bail!("checkpoint/resume requires global mode (local runs are one round)")
            }
        }
    }

    pub fn into_output(self) -> Result<MachineOutput> {
        match self {
            RunMachine::Global(g) => {
                let o = g.into_output()?;
                Ok(MachineOutput {
                    labels: o.labels,
                    centroids: o.centroids,
                    inertia: o.inertia,
                    inertia_trace: o.inertia_trace,
                    iterations: o.iterations,
                    converged: o.converged,
                    rounds: o.rounds,
                })
            }
            RunMachine::Local(l) => {
                let o = l.into_output()?;
                Ok(MachineOutput {
                    labels: o.labels,
                    centroids: o.centroids,
                    inertia: o.inertia,
                    inertia_trace: Vec::new(),
                    iterations: 1,
                    converged: true,
                    rounds: o.rounds,
                })
            }
        }
    }
}

/// Stable identity of a run's value-determining configuration: geometry
/// plus the clustering parameters and mode. Speed-only knobs (workers,
/// kernel, block shape, schedule, I/O model) are deliberately excluded —
/// per-round values depend only on the shipped centroids, so a
/// checkpoint taken under one performance configuration resumes
/// bit-identically under another. Shared by the solo coordinator and
/// the service so the two stamp (and verify) identical fingerprints.
pub fn run_fingerprint(
    height: usize,
    width: usize,
    channels: usize,
    ccfg: &ClusterConfig,
    mode: ClusterMode,
) -> u64 {
    let canon = format!(
        "blockms-run-v1|h={height}|w={width}|c={channels}|k={k}|seed={seed}\
         |tol={tol:08x}|max={max}|fixed={fixed:?}|init={init:?}|mode={mode:?}",
        k = ccfg.k,
        seed = ccfg.seed,
        tol = ccfg.tol.to_bits(),
        max = ccfg.max_iters,
        fixed = ccfg.fixed_iters,
        init = ccfg.init,
    );
    fnv1a(canon.as_bytes())
}

/// Process-wide sequence for solo runs' file-backed strip-store
/// directories: two concurrent runs with identical geometry must never
/// share a backing file (the service's job stores already do this via
/// `job_store_dir`; the pid keeps cross-process TMPDIR sharing safe).
static SOLO_STORE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

fn solo_store_dir() -> PathBuf {
    let seq = SOLO_STORE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("blockms_solo_p{}_{seq}", std::process::id()))
}

/// The leader. See module docs.
#[derive(Clone, Debug, Default)]
pub struct Coordinator {
    cfg: CoordinatorConfig,
    /// When set, [`Coordinator::cluster`] distributes blocks to shard
    /// processes instead of spawning in-process workers (see
    /// [`crate::shard`]). Deliberately not a [`CoordinatorConfig`]
    /// field: sharding changes where compute *runs*, not what the run
    /// computes, and existing config construction sites stay valid.
    shards: Option<ShardEndpoints>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        assert!(cfg.exec.workers > 0, "need at least one worker");
        Coordinator { cfg, shards: None }
    }

    /// Distribute this coordinator's runs across shard processes. The
    /// plan's `workers` becomes the connection count **per shard** (so
    /// blocks pipeline per shard exactly like local worker threads).
    pub fn with_shards(mut self, endpoints: ShardEndpoints) -> Coordinator {
        self.shards = Some(endpoints);
        self
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// The block tiling this coordinator's plan yields for an image —
    /// derived from [`ExecPlan::shape`], so the solo path, the service,
    /// and any test asserting on block counts all see the same plan.
    pub fn block_plan(&self, img: &Raster) -> BlockPlan {
        self.cfg.exec.block_plan(img.height(), img.width())
    }

    /// Drive one machine to completion over a warm pool: optional
    /// checkpoint resume up front, per-round retry budget from the plan,
    /// and round-boundary checkpoint writes at the configured cadence.
    /// Shared by the in-memory and streaming entry points so their
    /// fault-tolerance behaviour cannot drift.
    fn drive(&self, machine: &mut RunMachine, pool: &WorkerPool, fingerprint: u64) -> Result<()> {
        if let Some(path) = &self.cfg.resume {
            let ck = Checkpoint::load(path)?;
            anyhow::ensure!(
                ck.fingerprint == fingerprint,
                "checkpoint {} was taken by a different run configuration \
                 (fingerprint {:#018x}, this run {:#018x})",
                path.display(),
                ck.fingerprint,
                fingerprint
            );
            machine.restore(&ck)?;
        }
        let retries = self.cfg.exec.retries;
        let every = self.cfg.exec.checkpoint_every;
        pool.set_speculate(self.cfg.exec.speculate);
        // A deadline is enforced at round boundaries only: a round in
        // flight always completes (values are never truncated), then
        // the run stops with a best-effort checkpoint so it is
        // *resumable*, not lost.
        let deadline_ms = self.cfg.exec.deadline_ms;
        let deadline = (deadline_ms > 0)
            .then(|| std::time::Instant::now() + std::time::Duration::from_millis(deadline_ms as u64));
        let mut rounds_done = 0usize;
        while !machine.done() {
            let jobs = machine.start_round(SOLO_JOB);
            for outcome in pool.run_round_resilient(jobs, retries)? {
                if machine.wants(&outcome) {
                    machine.absorb(outcome)?;
                }
            }
            machine.finish_round()?;
            rounds_done += 1;
            if let Some(d) = deadline {
                if std::time::Instant::now() >= d && !machine.done() {
                    let saved = match (&self.cfg.checkpoint, machine.snapshot(fingerprint)) {
                        (Some(path), Some(ck)) => {
                            ck.save(path).with_context(|| {
                                format!("writing deadline checkpoint {}", path.display())
                            })?;
                            format!("checkpoint written to {} (resume with --resume)", path.display())
                        }
                        _ => "no checkpoint path configured; progress discarded".to_string(),
                    };
                    anyhow::bail!(
                        "deadline of {deadline_ms}ms hit after {rounds_done} rounds; {saved}"
                    );
                }
            }
            if every > 0 && rounds_done % every == 0 && !machine.done() {
                if let Some(path) = &self.cfg.checkpoint {
                    if let Some(ck) = machine.snapshot(fingerprint) {
                        ck.save(path)
                            .with_context(|| format!("writing checkpoint {}", path.display()))?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Cluster `img` using the parallel block pipeline under this
    /// coordinator's resolved [`ExecPlan`] (the block tiling is derived
    /// from the plan's shape — there is no separate plan argument to
    /// drift out of sync).
    pub fn cluster(&self, img: &Arc<Raster>, ccfg: &ClusterConfig) -> Result<ClusterOutput> {
        if let Some(endpoints) = &self.shards {
            return self.cluster_sharded(img, ccfg, endpoints);
        }
        let plan = Arc::new(self.block_plan(img));
        let t0 = std::time::Instant::now();

        // Shared init draw — identical to the sequential baseline's.
        let init_centroids = ccfg
            .init
            .centroids(img.as_pixels(), ccfg.k, img.channels(), ccfg.seed);

        // Materialize the block source. File backing gets a run-unique
        // directory so concurrent same-geometry runs never share (or
        // truncate) each other's backing file.
        let mut store_dir = None;
        let (source, store) = match &self.cfg.io {
            IoMode::Direct => (BlockSource::Direct(Arc::clone(img)), None),
            IoMode::Strips {
                strip_rows,
                file_backed,
            } => {
                let backing = if *file_backed {
                    let dir = solo_store_dir();
                    store_dir = Some(dir.clone());
                    Backing::File(dir)
                } else {
                    Backing::Memory
                };
                let mut store = StripStore::new(img, *strip_rows, backing)?;
                store.enable_cache(self.cfg.exec.strip_cache);
                let store = Arc::new(store);
                (BlockSource::Strips(Arc::clone(&store)), Some(store))
            }
        };

        let ctx = Arc::new(WorkerContext {
            plan: Arc::clone(&plan),
            source,
            backend: self.cfg.engine.backend_spec(ccfg.k, img.channels())?,
            fault: self.cfg.fault.clone(),
            local_mode: self.cfg.mode == ClusterMode::Local,
            exec: self.cfg.exec,
            content: SOLO_JOB,
        });
        let pool = WorkerPool::spawn(self.cfg.exec.workers, self.cfg.schedule);
        if self.cfg.exec.heartbeat_ms > 0 {
            pool.set_heartbeat_timeout_ms(self.cfg.exec.heartbeat_ms as u64);
        }
        pool.register_job(SOLO_JOB, ctx);
        let spawn_secs = pool.warmup(SOLO_JOB)?;

        let mut machine = RunMachine::new(
            self.cfg.mode,
            Arc::clone(&plan),
            img.channels(),
            ccfg,
            init_centroids,
            None,
        );
        let fingerprint =
            run_fingerprint(img.height(), img.width(), img.channels(), ccfg, self.cfg.mode);
        let drove = self.drive(&mut machine, &pool, fingerprint);
        // Wake any still-parked hang victim before joining: the run is
        // over (finished, stalled out, or deadlined) and a parked
        // worker would otherwise block the join for the rest of its
        // park, turning a bounded recovery into an unbounded teardown.
        if let Some(f) = &self.cfg.fault {
            f.release();
        }
        drove?;
        pool.shutdown();
        let m = machine.into_output()?;

        let io_stats = store.as_ref().map(|s| s.stats().snapshot());
        // Workers are joined: dropping the last store handle removes the
        // backing file, then its run-unique directory can go too.
        drop(store);
        if let Some(dir) = store_dir {
            let _ = std::fs::remove_dir(&dir);
        }
        ClusterOutput::from_machine(
            m,
            t0.elapsed().as_secs_f64(),
            spawn_secs,
            io_stats,
            plan.len(),
            self.cfg.exec.workers,
        )
    }

    /// Distributed clustering: the same round protocol as [`Coordinator::cluster`],
    /// but every block executes in a shard process (or loopback shard
    /// thread) reached over a [`crate::shard::ShardTransport`]. The
    /// leader never touches pixels after the [`ShardSpec`] ships: it
    /// sends centroids + drift down, merges per-block partial sums back
    /// in deterministic block order, so labels, centroids, counts, and
    /// inertia are bit-identical to a solo run of the same plan.
    ///
    /// A shard dying mid-round surfaces as transport errors on its
    /// in-flight blocks; the dynamic queue plus the PR 6/8 retry and
    /// watchdog machinery re-queues those blocks onto surviving shards
    /// (every shard holds the full spec, so any shard can compute any
    /// block).
    fn cluster_sharded(
        &self,
        img: &Arc<Raster>,
        ccfg: &ClusterConfig,
        endpoints: &ShardEndpoints,
    ) -> Result<ClusterOutput> {
        if !matches!(self.cfg.engine, Engine::Native) {
            anyhow::bail!(
                "sharded execution supports the native engine only (PJRT artifacts are per-process)"
            );
        }
        if self.cfg.fault.is_some() {
            anyhow::bail!(
                "fault injection targets in-process workers; it cannot cross the shard boundary"
            );
        }
        let plan = Arc::new(self.block_plan(img));
        let t0 = std::time::Instant::now();

        // Same init draw as solo — the leader draws, shards receive.
        let init_centroids = ccfg
            .init
            .centroids(img.as_pixels(), ccfg.k, img.channels(), ccfg.seed);

        let spec = Arc::new(ShardSpec::from_run(
            img,
            ccfg,
            self.cfg.mode,
            &self.cfg.io,
            &self.cfg.exec,
        ));
        // `--workers` becomes connections per shard: blocks pipeline
        // into each shard with the same depth a local pool would have.
        let (pool, guards) = spawn_shard_pool(endpoints, self.cfg.exec.workers)?;
        if self.cfg.exec.heartbeat_ms > 0 {
            pool.set_heartbeat_timeout_ms(self.cfg.exec.heartbeat_ms as u64);
        }
        pool.register_shard_spec(SOLO_JOB, spec);
        // Warmup's per-connection Ping doubles as eager registration:
        // every shard materializes the job before round 1, so byte
        // counts are deterministic and round latency is flat.
        let spawn_secs = pool.warmup(SOLO_JOB)?;

        let mut machine = RunMachine::new(
            self.cfg.mode,
            Arc::clone(&plan),
            img.channels(),
            ccfg,
            init_centroids,
            None,
        );
        let fingerprint =
            run_fingerprint(img.height(), img.width(), img.channels(), ccfg, self.cfg.mode);
        let drove = self.drive(&mut machine, &pool, fingerprint);
        // Teardown order matters for loopback shards: shutting the pool
        // down drops the proxy-side transports, which is what lets the
        // shard-side handler threads (joined by the guards' Drop) see
        // `Closed` and exit.
        pool.shutdown();
        drop(guards);
        drove?;
        let m = machine.into_output()?;

        ClusterOutput::from_machine(
            m,
            t0.elapsed().as_secs_f64(),
            spawn_secs,
            None, // I/O happens shard-side; the leader has no store to audit.
            plan.len(),
            self.cfg.exec.workers * endpoints.shards(),
        )
    }

    /// Out-of-core clustering: stream pixels from any [`RasterSource`]
    /// into a strip store (one strip resident at a time under file
    /// backing), draw initial centroids in the same single pass
    /// ([`StreamInit`] — bit-identical to the in-memory draw), run the
    /// identical round machinery over strip I/O, and deliver labels
    /// through a budgeted [`crate::blocks::LabelSink`].
    ///
    /// Requires [`IoMode::Strips`] (there is no raster to crop from).
    /// The strip store is file-backed when either the I/O mode or the
    /// plan ([`ExecPlan::file_backed`]) says so. With a `mem_mb` budget
    /// on the plan, labels spool to disk and the returned
    /// [`StreamRun::peak_resident_bytes`] reports the audited
    /// high-water mark of resident pixel bytes.
    ///
    /// Bit-identity contract (tested in `tests/integration_pipeline.rs`):
    /// the same source description run through [`Coordinator::cluster`]
    /// on a materialized raster produces identical labels, centroids,
    /// counts, and inertia — same strips, same block order, same f32 op
    /// order.
    pub fn cluster_source(
        &self,
        source: &mut dyn RasterSource,
        ccfg: &ClusterConfig,
    ) -> Result<StreamRun> {
        if self.shards.is_some() {
            anyhow::bail!(
                "streaming ingestion is not yet supported with --shards (shards need the full raster in the spec)"
            );
        }
        let IoMode::Strips {
            strip_rows,
            file_backed,
        } = self.cfg.io
        else {
            anyhow::bail!("streaming ingestion requires IoMode::Strips (Direct has no source)");
        };
        let (height, width, channels) = (source.height(), source.width(), source.channels());
        let plan = Arc::new(self.cfg.exec.block_plan(height, width));
        let t0 = std::time::Instant::now();

        // Single ingestion pass: strips flow source → store while the
        // init sampler observes them. Same draw as the in-memory path.
        let mut sampler =
            StreamInit::new(&ccfg.init, ccfg.k, channels, Some(height * width), ccfg.seed)?;
        let mut store_dir = None;
        let backing = if file_backed || self.cfg.exec.file_backed {
            let dir = solo_store_dir();
            store_dir = Some(dir.clone());
            Backing::File(dir)
        } else {
            Backing::Memory
        };
        let mut store =
            StripStore::ingest(source, strip_rows, backing, |_, strip| sampler.feed(strip))?;
        store.enable_cache(self.cfg.exec.strip_cache);
        let store = Arc::new(store);
        let init_centroids = sampler.finish()?;

        let ctx = Arc::new(WorkerContext {
            plan: Arc::clone(&plan),
            source: BlockSource::Strips(Arc::clone(&store)),
            backend: self.cfg.engine.backend_spec(ccfg.k, channels)?,
            fault: self.cfg.fault.clone(),
            local_mode: self.cfg.mode == ClusterMode::Local,
            exec: self.cfg.exec,
            content: SOLO_JOB,
        });
        let pool = WorkerPool::spawn(self.cfg.exec.workers, self.cfg.schedule);
        if self.cfg.exec.heartbeat_ms > 0 {
            pool.set_heartbeat_timeout_ms(self.cfg.exec.heartbeat_ms as u64);
        }
        pool.register_job(SOLO_JOB, ctx);
        let spawn_secs = pool.warmup(SOLO_JOB)?;

        // Under a budget the label map spools — the same rule the
        // planner's resident model applies, so prediction and runtime
        // agree about where labels live.
        let label_budget = self.cfg.exec.mem_budget_bytes().map(|_| 0);
        let mut machine = RunMachine::new(
            self.cfg.mode,
            Arc::clone(&plan),
            channels,
            ccfg,
            init_centroids,
            label_budget,
        );
        let fingerprint = run_fingerprint(height, width, channels, ccfg, self.cfg.mode);
        let drove = self.drive(&mut machine, &pool, fingerprint);
        // Same latch rule as `cluster`: a parked hang victim must not
        // outlive the run into the join below.
        if let Some(f) = &self.cfg.fault {
            f.release();
        }
        drove?;
        pool.shutdown();
        let m = machine.into_output()?;
        let io_stats = store.stats().snapshot();
        drop(store); // last handle: backing file's Drop runs
        if let Some(dir) = store_dir {
            let _ = std::fs::remove_dir(&dir);
        }

        Ok(StreamRun {
            labels: m.labels,
            centroids: m.centroids,
            inertia: m.inertia,
            inertia_trace: m.inertia_trace,
            iterations: m.iterations,
            converged: m.converged,
            total_secs: t0.elapsed().as_secs_f64(),
            spawn_secs,
            rounds: m.rounds,
            peak_resident_bytes: io_stats.peak_resident_bytes,
            io_stats,
            blocks: plan.len(),
            workers: self.cfg.exec.workers,
            height,
            width,
        })
    }

    /// The sequential baseline with the same init draw — the paper's
    /// "Serial" column. Uses the same engine choice so serial-vs-parallel
    /// compares coordination, not compute implementations: `Native` runs
    /// [`SeqKMeans`] directly; `Pjrt` runs the whole image as one block
    /// through a single-worker pool.
    pub fn serial(&self, img: &Arc<Raster>, ccfg: &ClusterConfig) -> Result<ClusterOutput> {
        match &self.cfg.engine {
            Engine::Native => {
                let t0 = std::time::Instant::now();
                let r = match ccfg.fixed_iters {
                    Some(n) => SeqKMeans::run_fixed_iters_with(
                        img.as_pixels(),
                        img.channels(),
                        &ccfg.kmeans(),
                        n,
                        self.cfg.exec.kernel,
                    ),
                    None => SeqKMeans::run_with(
                        img.as_pixels(),
                        img.channels(),
                        &ccfg.kmeans(),
                        self.cfg.exec.kernel,
                    ),
                };
                Ok(ClusterOutput {
                    labels: r.labels,
                    centroids: r.centroids,
                    inertia: r.inertia,
                    inertia_trace: Vec::new(),
                    iterations: r.iterations,
                    converged: r.converged,
                    total_secs: t0.elapsed().as_secs_f64(),
                    spawn_secs: 0.0,
                    rounds: Vec::new(),
                    io_stats: None,
                    blocks: 1,
                    workers: 1,
                })
            }
            Engine::Pjrt { .. } => {
                // One whole-image block on one worker: the same engine,
                // no coordination.
                let whole = crate::blocks::BlockShape::Custom {
                    rows: img.height(),
                    cols: img.width(),
                };
                let serial_coord = Coordinator::new(CoordinatorConfig {
                    exec: self.cfg.exec.with_shape(whole).with_workers(1),
                    mode: ClusterMode::Global,
                    io: IoMode::Direct,
                    ..self.cfg.clone()
                });
                serial_coord.cluster(img, ccfg)
            }
        }
    }
}

// Re-export the access snapshot, tile layout, and execution plan so
// callers don't need the stripstore / kmeans / plan paths.
pub use crate::kmeans::tile::TileLayout as BlockLayout;
pub use crate::plan::ExecPlan as Plan;
pub use crate::stripstore::AccessSnapshot;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::image::SyntheticOrtho;
    use crate::kmeans::kernel::KernelChoice;
    use crate::kmeans::tile::TileLayout;

    fn image(h: usize, w: usize) -> Arc<Raster> {
        Arc::new(SyntheticOrtho::default().with_seed(21).generate(h, w))
    }

    fn square(side: usize) -> BlockShape {
        BlockShape::Square { side }
    }

    fn cfg(shape: BlockShape, workers: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            exec: ExecPlan::pinned(shape).with_workers(workers),
            ..Default::default()
        }
    }

    fn strips(rows: usize) -> IoMode {
        IoMode::Strips {
            strip_rows: rows,
            file_backed: false,
        }
    }

    #[test]
    fn global_mode_equals_sequential_exactly() {
        let img = image(60, 50);
        for k in [2, 4] {
            let ccfg = ClusterConfig {
                k,
                ..Default::default()
            };
            let coord = Coordinator::new(cfg(square(17), 3));
            let par = coord.cluster(&img, &ccfg).unwrap();
            let seq = coord.serial(&img, &ccfg).unwrap();
            assert_eq!(par.labels, seq.labels, "k={k}: labels differ");
            assert_eq!(par.centroids, seq.centroids, "k={k}: centroids differ");
            assert_eq!(par.iterations, seq.iterations);
            assert_eq!(par.converged, seq.converged);
            assert!((par.inertia - seq.inertia).abs() < 1e-6);
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let img = image(40, 45);
        let ccfg = ClusterConfig {
            k: 4,
            ..Default::default()
        };
        let mut outputs = Vec::new();
        for workers in [1, 2, 5] {
            let coord = Coordinator::new(cfg(square(13), workers));
            outputs.push(coord.cluster(&img, &ccfg).unwrap());
        }
        assert_eq!(outputs[0].labels, outputs[1].labels);
        assert_eq!(outputs[1].labels, outputs[2].labels);
        assert_eq!(outputs[0].centroids, outputs[2].centroids);
    }

    #[test]
    fn block_shape_does_not_change_global_results() {
        let img = image(48, 36);
        let ccfg = ClusterConfig {
            k: 2,
            ..Default::default()
        };
        let mut first: Option<ClusterOutput> = None;
        for shape in [
            BlockShape::Rows { band_rows: 10 },
            BlockShape::Cols { band_cols: 7 },
            BlockShape::Square { side: 16 },
        ] {
            let out = Coordinator::new(cfg(shape, 4))
                .cluster(&img, &ccfg)
                .unwrap();
            if let Some(f) = &first {
                assert_eq!(f.labels, out.labels, "{shape} diverged");
                assert_eq!(f.centroids, out.centroids);
            } else {
                first = Some(out);
            }
        }
    }

    #[test]
    fn pruned_and_fused_kernels_match_naive_globally() {
        let img = image(52, 44);
        for schedule in [Schedule::Static, Schedule::Dynamic] {
            for k in [2usize, 4] {
                let ccfg = ClusterConfig {
                    k,
                    ..Default::default()
                };
                let naive = Coordinator::new(CoordinatorConfig {
                    schedule,
                    ..cfg(square(15), 3)
                })
                .cluster(&img, &ccfg)
                .unwrap();
                for kernel in [
                    KernelChoice::Pruned,
                    KernelChoice::Fused,
                    KernelChoice::Lanes,
                    KernelChoice::Simd,
                ] {
                    let coord = Coordinator::new(CoordinatorConfig {
                        exec: ExecPlan::pinned(square(15)).with_workers(3).with_kernel(kernel),
                        schedule,
                        ..Default::default()
                    });
                    let out = coord.cluster(&img, &ccfg).unwrap();
                    assert_eq!(out.labels, naive.labels, "k={k} {kernel} {schedule:?}");
                    assert_eq!(out.centroids, naive.centroids, "k={k} {kernel} {schedule:?}");
                    assert_eq!(out.iterations, naive.iterations);
                    assert_eq!(out.inertia_trace, naive.inertia_trace);
                    // and the serial mirror under the same kernel agrees too
                    let seq = coord.serial(&img, &ccfg).unwrap();
                    assert_eq!(out.labels, seq.labels);
                    assert_eq!(out.centroids, seq.centroids);
                }
            }
        }
    }

    #[test]
    fn strip_io_counts_accesses() {
        let img = image(40, 30);
        let ccfg = ClusterConfig {
            k: 2,
            fixed_iters: Some(3),
            ..Default::default()
        };
        let coord = Coordinator::new(CoordinatorConfig {
            io: strips(8),
            ..cfg(square(12), 2)
        });
        let plan = coord.block_plan(&img);
        let out = coord.cluster(&img, &ccfg).unwrap();
        let stats = out.io_stats.expect("strip mode must report stats");
        // 3 step rounds + 1 assign round = 4 passes over all blocks
        let (per_pass, _, _) = crate::stripstore::read_amplification(&plan, 8);
        assert_eq!(stats.strip_reads as usize, per_pass * 4);
        assert_eq!(stats.block_reads as usize, plan.len() * 4);
    }

    #[test]
    fn soa_arena_reads_each_block_once_per_job() {
        // The acceptance invariant of the tile arena: with the SoA
        // layout and a budget that fits every tile, the strip store is
        // touched once per block per JOB, not once per block per round.
        let img = image(40, 30);
        let ccfg = ClusterConfig {
            k: 2,
            fixed_iters: Some(3),
            ..Default::default()
        };
        let coord = Coordinator::new(CoordinatorConfig {
            // Lanes resolves to TileLayout::Soa.
            exec: ExecPlan::pinned(square(12))
                .with_workers(2)
                .with_kernel(KernelChoice::Lanes),
            // Static: block ownership is stable across rounds, so each
            // per-worker arena fills its blocks exactly once. (Dynamic
            // migration would refill on the new worker — correct, just
            // not closed-form.)
            schedule: Schedule::Static,
            io: strips(8),
            ..Default::default()
        });
        let plan = coord.block_plan(&img);
        let out = coord.cluster(&img, &ccfg).unwrap();
        let stats = out.io_stats.expect("strip mode must report stats");
        // 3 step rounds + 1 assign round, but every block is filled once.
        let (per_pass, _, _) = crate::stripstore::read_amplification(&plan, 8);
        assert_eq!(stats.strip_reads as usize, per_pass);
        assert_eq!(stats.block_reads as usize, plan.len());
        // …and the result is still bit-identical to the naive seed path.
        let naive = Coordinator::new(CoordinatorConfig {
            schedule: Schedule::Static,
            ..cfg(square(12), 2)
        })
        .cluster(&img, &ccfg)
        .unwrap();
        assert_eq!(out.labels, naive.labels);
        assert_eq!(out.centroids, naive.centroids);
    }

    #[test]
    fn zero_arena_budget_spills_to_per_round_reads() {
        let img = image(40, 30);
        let ccfg = ClusterConfig {
            k: 2,
            fixed_iters: Some(3),
            ..Default::default()
        };
        let coord = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(square(12))
                .with_workers(2)
                .with_kernel(KernelChoice::Lanes)
                .with_arena_mb(0), // nothing fits: every fill spills
            schedule: Schedule::Static,
            io: strips(8),
            ..Default::default()
        });
        let plan = coord.block_plan(&img);
        let out = coord.cluster(&img, &ccfg).unwrap();
        let stats = out.io_stats.expect("strip mode must report stats");
        let (per_pass, _, _) = crate::stripstore::read_amplification(&plan, 8);
        assert_eq!(stats.strip_reads as usize, per_pass * 4); // seed behaviour
        assert_eq!(stats.block_reads as usize, plan.len() * 4);
    }

    #[test]
    fn soa_layout_is_bit_identical_for_interleaved_kernels() {
        // Forcing the arena under naive/pruned kernels changes only the
        // I/O shape (fill once, rematerialize per round) — never values.
        let img = image(52, 44);
        let ccfg = ClusterConfig {
            k: 4,
            ..Default::default()
        };
        let naive = Coordinator::new(cfg(square(15), 4))
            .cluster(&img, &ccfg)
            .unwrap();
        for kernel in [KernelChoice::Naive, KernelChoice::Pruned] {
            let out = Coordinator::new(CoordinatorConfig {
                exec: ExecPlan::pinned(square(15))
                    .with_workers(3)
                    .with_kernel(kernel)
                    .with_layout(TileLayout::Soa),
                ..Default::default()
            })
            .cluster(&img, &ccfg)
            .unwrap();
            assert_eq!(out.labels, naive.labels, "{kernel}");
            assert_eq!(out.centroids, naive.centroids, "{kernel}");
        }
    }

    #[test]
    fn prefetch_changes_timing_not_values() {
        let img = image(48, 40);
        let ccfg = ClusterConfig {
            k: 4,
            ..Default::default()
        };
        for schedule in [Schedule::Static, Schedule::Dynamic] {
            let plain = Coordinator::new(CoordinatorConfig {
                schedule,
                io: strips(8),
                ..cfg(square(11), 2)
            })
            .cluster(&img, &ccfg)
            .unwrap();
            for kernel in [KernelChoice::Naive, KernelChoice::Lanes] {
                let out = Coordinator::new(CoordinatorConfig {
                    exec: ExecPlan::pinned(square(11))
                        .with_workers(2)
                        .with_kernel(kernel)
                        .with_prefetch(true),
                    schedule,
                    io: strips(8),
                    ..Default::default()
                })
                .cluster(&img, &ccfg)
                .unwrap();
                assert_eq!(out.labels, plain.labels, "{kernel} {schedule:?}");
                assert_eq!(out.centroids, plain.centroids, "{kernel} {schedule:?}");
                assert_eq!(out.iterations, plain.iterations);
            }
        }
    }

    #[test]
    fn strip_cache_collapses_column_amplification() {
        let img = image(40, 30);
        let ccfg = ClusterConfig {
            k: 2,
            fixed_iters: Some(2),
            ..Default::default()
        };
        let coord = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(BlockShape::Cols { band_cols: 7 })
                .with_workers(1) // deterministic access sequence
                .with_strip_cache(5), // all strips of a 40-row image at strip_rows 8
            io: strips(8),
            ..Default::default()
        });
        let out = coord.cluster(&img, &ccfg).unwrap();
        let stats = out.io_stats.expect("strip mode must report stats");
        // 5 column blocks × 5 strips × 3 passes = 75 accesses; only the
        // first touch of each strip transfers.
        assert_eq!(stats.strip_reads, 5);
        assert_eq!(stats.strip_cache_misses, 5);
        assert_eq!(stats.strip_cache_hits, 75 - 5);
    }

    #[test]
    fn local_mode_produces_coherent_labels() {
        let img = image(64, 64);
        let ccfg = ClusterConfig {
            k: 2,
            ..Default::default()
        };
        let coord = Coordinator::new(CoordinatorConfig {
            mode: ClusterMode::Local,
            ..cfg(square(32), 2)
        });
        let out = coord.cluster(&img, &ccfg).unwrap();
        assert_eq!(out.labels.len(), 64 * 64);
        assert!(out.labels.iter().all(|&l| l < 2));
        // Harmonized labels must agree with the global run on most pixels
        // (blocks see slightly different data, so not exact).
        let global = Coordinator::new(cfg(square(32), 4))
            .cluster(&img, &ccfg)
            .unwrap();
        let agree = out
            .labels
            .iter()
            .zip(&global.labels)
            .filter(|(a, b)| a == b)
            .count();
        let frac = agree as f64 / out.labels.len() as f64;
        // label polarity could be globally flipped; accept either
        let frac = frac.max(1.0 - frac);
        assert!(frac > 0.85, "local/global agreement too low: {frac}");
    }

    #[test]
    fn fixed_iters_runs_exact_count_and_matches_serial() {
        let img = image(30, 30);
        let ccfg = ClusterConfig {
            k: 2,
            fixed_iters: Some(5),
            ..Default::default()
        };
        let coord = Coordinator::new(cfg(square(9), 2));
        let par = coord.cluster(&img, &ccfg).unwrap();
        assert_eq!(par.iterations, 5);
        let seq = coord.serial(&img, &ccfg).unwrap();
        assert_eq!(par.labels, seq.labels);
        assert_eq!(par.centroids, seq.centroids);
    }

    #[test]
    fn failure_injection_surfaces_error() {
        let img = image(30, 30);
        let coord = Coordinator::new(CoordinatorConfig {
            fault: Some(FaultPlan::always(1, crate::resilience::FaultKind::Error)),
            ..cfg(square(10), 2)
        });
        let err = coord.cluster(&img, &ClusterConfig::default()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "{msg}");
    }

    #[test]
    fn transient_fault_recovers_bit_identically_under_retry() {
        let img = image(48, 40);
        let ccfg = ClusterConfig {
            k: 3,
            ..Default::default()
        };
        let clean = Coordinator::new(cfg(square(13), 3))
            .cluster(&img, &ccfg)
            .unwrap();
        // One block fails on its first two visits (both in round one —
        // retries re-queue immediately), then heals; a per-round budget
        // of 2 absorbs both.
        let fault = FaultPlan::new(2, crate::resilience::FaultKind::Error, 2);
        let out = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(square(13)).with_workers(3).with_retries(2),
            fault: Some(fault.clone()),
            ..Default::default()
        })
        .cluster(&img, &ccfg)
        .unwrap();
        assert!(fault.trips() >= 2, "fault never fired");
        assert_eq!(out.labels, clean.labels);
        assert_eq!(out.centroids, clean.centroids);
        assert_eq!(out.inertia_trace, clean.inertia_trace);
    }

    #[test]
    fn checkpoint_then_resume_is_bit_identical() {
        let img = image(48, 40);
        let ccfg = ClusterConfig {
            k: 3,
            fixed_iters: Some(6),
            ..Default::default()
        };
        let reference = Coordinator::new(cfg(square(13), 3))
            .cluster(&img, &ccfg)
            .unwrap();

        let dir = std::env::temp_dir().join(format!(
            "blockms_ckpt_test_p{}_{}",
            std::process::id(),
            SOLO_STORE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("run.ckpt");

        // First run checkpoints every 2 rounds, then is killed by an
        // always-on fault armed after round 4 — the kill/resume drill.
        let err = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(square(13))
                .with_workers(3)
                .with_checkpoint_every(2),
            checkpoint: Some(ckpt.clone()),
            // Block 1 is visited once per round, so skip=4 heals the
            // first four rounds and kills the run on round five.
            fault: Some(FaultPlan::always(1, crate::resilience::FaultKind::Error).after(4)),
            ..Default::default()
        })
        .cluster(&img, &ccfg)
        .unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
        assert!(ckpt.exists(), "no checkpoint written before the crash");

        // Resume from the survivor and finish; outputs must be bitwise
        // equal to the uninterrupted reference run.
        let out = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(square(13)).with_workers(3),
            resume: Some(ckpt.clone()),
            ..Default::default()
        })
        .cluster(&img, &ccfg)
        .unwrap();
        assert_eq!(out.labels, reference.labels);
        assert_eq!(out.centroids, reference.centroids);
        assert_eq!(out.iterations, reference.iterations);
        assert_eq!(out.inertia_trace, reference.inertia_trace);

        // A mismatched configuration must refuse the checkpoint.
        let err = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(square(13)).with_workers(3),
            resume: Some(ckpt.clone()),
            ..Default::default()
        })
        .cluster(
            &img,
            &ClusterConfig {
                k: 4,
                ..ccfg.clone()
            },
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("different run configuration"),
            "{err:#}"
        );

        let _ = std::fs::remove_file(&ckpt);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn block_plan_derives_from_the_exec_plan() {
        // The plan drift hazard is gone by construction: the tiling is
        // derived from the ExecPlan's shape against the actual image.
        let img = image(30, 30);
        let coord = Coordinator::new(cfg(square(10), 2));
        let plan = coord.block_plan(&img);
        assert_eq!(plan.len(), 9);
        assert_eq!(plan.block_dims(), (10, 10));
        let out = coord.cluster(&img, &ClusterConfig::default()).unwrap();
        assert_eq!(out.blocks, plan.len());
    }

    #[test]
    fn rounds_record_all_blocks() {
        let img = image(36, 36);
        let ccfg = ClusterConfig {
            k: 2,
            fixed_iters: Some(2),
            ..Default::default()
        };
        let coord = Coordinator::new(cfg(square(12), 2));
        let out = coord.cluster(&img, &ccfg).unwrap();
        // 2 step rounds + 1 assign
        assert_eq!(out.rounds.len(), 3);
        for r in &out.rounds {
            assert_eq!(r.costs.len(), coord.block_plan(&img).len());
            assert!(r.wall_secs >= 0.0);
        }
        assert_eq!(out.rounds[0].kind, RoundKind::Step);
        assert_eq!(out.rounds.last().unwrap().kind, RoundKind::Assign);
    }
}
