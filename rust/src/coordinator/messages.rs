//! Leader↔worker message types.
//!
//! Since the service layer landed, every message is tagged with a
//! [`JobId`]: one worker pool multiplexes blocks from many concurrent
//! clustering jobs, and workers key their per-block state (pruned
//! bounds, readers, backends) by job so interleaved jobs can never
//! contaminate each other. Single-run [`crate::coordinator::Coordinator`]
//! drives use the reserved [`SOLO_JOB`] id.

use std::sync::Arc;

use crate::kmeans::kernel::CentroidDrift;
use crate::kmeans::math::StepAccum;

/// Identifies one clustering job multiplexed over a shared worker pool.
/// Worker-side contexts are looked up per id in the pool's
/// [`crate::coordinator::ContextRegistry`].
pub type JobId = u64;

/// The job id a single-run `Coordinator` registers its context under.
/// The service allocates ids starting from 1, so the two can never
/// collide even if a solo run borrowed a service pool.
pub const SOLO_JOB: JobId = 0;

/// A unit of work: one block of one job, one operation.
#[derive(Clone, Debug)]
pub struct Job {
    /// Which clustering job this block belongs to.
    pub job: JobId,
    /// Index into the owning job's block plan.
    pub block: usize,
    /// Monotone per-job round number (sanity check against stale
    /// results; keys pruned-bounds continuity across rounds).
    pub round: u64,
    pub payload: JobPayload,
}

/// What to do with the block. Centroids are shared via `Arc` — one
/// allocation per round regardless of worker/block count.
#[derive(Clone, Debug)]
pub enum JobPayload {
    /// One Lloyd accumulation pass at the given centroids. `drift` is
    /// the per-centroid movement of the update that *produced* these
    /// centroids (`None` on the first round); workers running a pruned
    /// kernel use it to advance their per-(job, block) Hamerly bounds.
    Step {
        centroids: Arc<Vec<f32>>,
        drift: Option<Arc<CentroidDrift>>,
    },
    /// Final assignment at the given centroids. With the fused kernel
    /// and a valid per-(job, block) pruning state, workers reuse the
    /// last round's bounds instead of a from-scratch scan.
    Assign {
        centroids: Arc<Vec<f32>>,
        drift: Option<Arc<CentroidDrift>>,
    },
    /// Independent per-block K-Means from the given init.
    Local { init: Arc<Vec<f32>> },
    /// Readiness barrier: reply immediately (no block read, no compute
    /// beyond backend warmup). Used by the leader to absorb worker
    /// startup (PJRT client build + artifact compile — the
    /// parpool-startup analogue) before any timed round begins.
    Ping,
    /// The tagged job is finished (completed, failed, or cancelled):
    /// drop any cached per-job worker state (reader, backend, pruned
    /// bounds). Produces **no** reply message — the leader does not
    /// count retirements.
    ///
    /// `purge_content` names the *content id* whose decoded arena
    /// tiles should be evicted alongside, if any. Unshared jobs purge
    /// their own id; sweep variants sharing one image purge `None`
    /// until the last member of the share group retires (the leader
    /// knows the refcount, workers do not).
    Retire { purge_content: Option<u64> },
}

/// Per-block timing breakdown (feeds the simtime calibration).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BlockTiming {
    /// Seconds spent reading/cropping the block.
    pub io_secs: f64,
    /// Seconds spent in the compute backend.
    pub compute_secs: f64,
    /// Pixels processed.
    pub pixels: usize,
}

impl BlockTiming {
    pub fn total(&self) -> f64 {
        self.io_secs + self.compute_secs
    }
}

/// Result of one job.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    /// The clustering job this outcome belongs to (routing key when many
    /// jobs share one pool).
    pub job: JobId,
    pub block: usize,
    pub round: u64,
    pub worker: usize,
    pub timing: BlockTiming,
    pub result: JobResult,
}

#[derive(Clone, Debug)]
pub enum JobResult {
    Step {
        accum: StepAccum,
    },
    Assign {
        labels: Vec<u32>,
        inertia: f64,
    },
    Local {
        labels: Vec<u32>,
        centroids: Vec<f32>,
        inertia: f64,
        /// Per-cluster pixel counts at the final assignment (used by the
        /// leader for count-weighted harmonization).
        counts: Vec<u64>,
    },
    /// Reply to [`JobPayload::Ping`].
    Pong,
}

/// A worker-side failure, tagged with the job it belongs to so a shared
/// pool can fail one job without tearing down the others. The failing
/// worker evicts its own per-(job, block) state (pruned bounds, arena
/// tile) *before* sending this, so a leader that re-queues the block
/// under a retry budget gets a from-scratch — and therefore
/// bit-identical — recomputation from the round's shipped centroids.
#[derive(Debug)]
pub struct JobError {
    pub job: JobId,
    pub block: usize,
    pub error: anyhow::Error,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "job {} block {}: {:#}",
            self.job, self.block, self.error
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_is_cheap_to_clone() {
        let cen = Arc::new(vec![0.0f32; 6]);
        let job = Job {
            job: SOLO_JOB,
            block: 3,
            round: 1,
            payload: JobPayload::Step {
                centroids: Arc::clone(&cen),
                drift: None,
            },
        };
        let j2 = job.clone();
        match (&job.payload, &j2.payload) {
            (JobPayload::Step { centroids: a, .. }, JobPayload::Step { centroids: b, .. }) => {
                assert!(Arc::ptr_eq(a, b), "clone must share the centroid buffer");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn timing_total() {
        let t = BlockTiming {
            io_secs: 0.25,
            compute_secs: 0.5,
            pixels: 100,
        };
        assert!((t.total() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn job_error_names_job_and_block() {
        let e = JobError {
            job: 7,
            block: 3,
            error: anyhow::anyhow!("boom"),
        };
        let msg = e.to_string();
        assert!(msg.contains("job 7") && msg.contains("block 3") && msg.contains("boom"));
    }
}
