//! Leader↔worker message types.

use std::sync::Arc;

use crate::kmeans::kernel::CentroidDrift;
use crate::kmeans::math::StepAccum;

/// A unit of work: one block, one operation.
#[derive(Clone, Debug)]
pub struct Job {
    /// Index into the block plan.
    pub block: usize,
    /// Monotone round number (sanity check against stale results).
    pub round: u64,
    pub payload: JobPayload,
}

/// What to do with the block. Centroids are shared via `Arc` — one
/// allocation per round regardless of worker/block count.
#[derive(Clone, Debug)]
pub enum JobPayload {
    /// One Lloyd accumulation pass at the given centroids. `drift` is
    /// the per-centroid movement of the update that *produced* these
    /// centroids (`None` on the first round); workers running a pruned
    /// kernel use it to advance their per-block Hamerly bounds.
    Step {
        centroids: Arc<Vec<f32>>,
        drift: Option<Arc<CentroidDrift>>,
    },
    /// Final assignment at the given centroids. With the fused kernel
    /// and a valid per-block pruning state, workers reuse the last
    /// round's bounds instead of a from-scratch scan.
    Assign {
        centroids: Arc<Vec<f32>>,
        drift: Option<Arc<CentroidDrift>>,
    },
    /// Independent per-block K-Means from the given init.
    Local { init: Arc<Vec<f32>> },
    /// Readiness barrier: reply immediately (no block read, no compute).
    /// Used by the leader to absorb worker startup (PJRT client build +
    /// artifact compile — the parpool-startup analogue) before any timed
    /// round begins.
    Ping,
}

/// Per-block timing breakdown (feeds the simtime calibration).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BlockTiming {
    /// Seconds spent reading/cropping the block.
    pub io_secs: f64,
    /// Seconds spent in the compute backend.
    pub compute_secs: f64,
    /// Pixels processed.
    pub pixels: usize,
}

impl BlockTiming {
    pub fn total(&self) -> f64 {
        self.io_secs + self.compute_secs
    }
}

/// Result of one job.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub block: usize,
    pub round: u64,
    pub worker: usize,
    pub timing: BlockTiming,
    pub result: JobResult,
}

#[derive(Clone, Debug)]
pub enum JobResult {
    Step {
        accum: StepAccum,
    },
    Assign {
        labels: Vec<u32>,
        inertia: f64,
    },
    Local {
        labels: Vec<u32>,
        centroids: Vec<f32>,
        inertia: f64,
        /// Per-cluster pixel counts at the final assignment (used by the
        /// leader for count-weighted harmonization).
        counts: Vec<u64>,
    },
    /// Reply to [`JobPayload::Ping`].
    Pong,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_is_cheap_to_clone() {
        let cen = Arc::new(vec![0.0f32; 6]);
        let job = Job {
            block: 3,
            round: 1,
            payload: JobPayload::Step {
                centroids: Arc::clone(&cen),
                drift: None,
            },
        };
        let j2 = job.clone();
        match (&job.payload, &j2.payload) {
            (JobPayload::Step { centroids: a, .. }, JobPayload::Step { centroids: b, .. }) => {
                assert!(Arc::ptr_eq(a, b), "clone must share the centroid buffer");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn timing_total() {
        let t = BlockTiming {
            io_secs: 0.25,
            compute_secs: 0.5,
            pixels: 100,
        };
        assert!((t.total() - 0.75).abs() < 1e-12);
    }
}
