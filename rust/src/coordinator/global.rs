//! Global-mode parallel K-Means: one clustering over the whole image,
//! expressed as an incremental per-job state machine.
//!
//! Each Lloyd iteration is a round: workers produce per-block partial
//! accumulations at the current centroids; the leader merges them
//! (associative f64 reduction **in block order**), updates centroids,
//! and tests convergence. Because the merged accumulation is
//! *identical* to the sequential baseline's whole-image pass, global
//! mode reproduces `SeqKMeans` exactly — same labels, same centroids,
//! same iteration count — which the integration tests assert.
//! Parallelism changes time, not results.
//!
//! [`GlobalState`] holds one job's reduction state between rounds, so a
//! multi-job leader (the service) can interleave many jobs over one
//! pool: outcomes are buffered per block as they stream in (any order,
//! any worker) and reduced only when the round is complete, in ascending
//! block order — the same order the solo barrier produced, which is what
//! keeps service runs bit-identical to solo runs.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use super::messages::{Job, JobId, JobOutcome, JobPayload, JobResult};
use super::{BlockCost, RoundKind, RoundRecord};
use crate::blocks::{BlockPlan, LabelMap, LabelSink};
use crate::kmeans::kernel::{drift_between, CentroidDrift};
use crate::kmeans::math::{self, StepAccum};
use crate::kmeans::KMeansConfig;
use crate::resilience::{Checkpoint, CheckpointPhase};

/// Which phase a global job is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlobalPhase {
    /// Lloyd step rounds (centroid refinement).
    Step,
    /// The final labeling round.
    Assign,
    /// All rounds complete; output is ready.
    Done,
}

/// Completed output of a global-mode run. Labels arrive as a
/// [`LabelMap`]: dense in memory on the default path, spooled to disk
/// when the run was built with a label budget (see [`LabelSink`]).
#[derive(Debug)]
pub struct GlobalOutput {
    pub labels: LabelMap,
    pub centroids: Vec<f32>,
    pub inertia: f64,
    /// Inertia measured at the centroids *entering* each step round
    /// (monotone non-increasing — a tested Lloyd invariant).
    pub inertia_trace: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub rounds: Vec<RoundRecord>,
}

/// One job's between-round reduction state. Drive it with
/// [`GlobalState::start_round`] → absorb every outcome →
/// [`GlobalState::finish_round`], until [`GlobalState::done`].
pub struct GlobalState {
    plan: Arc<BlockPlan>,
    channels: usize,
    k: usize,
    tol: f32,
    /// Maximum step rounds (fixed-iteration runs disable the tol test).
    max_rounds: usize,
    fixed: bool,
    phase: GlobalPhase,
    centroids: Vec<f32>,
    /// Movement of the update that produced the current centroids
    /// (`None` before the first update); shipped with each round so
    /// pruned workers can advance their per-(job, block) bounds.
    drift: Option<Arc<CentroidDrift>>,
    iterations: usize,
    converged: bool,
    inertia_trace: Vec<f64>,
    rounds: Vec<RoundRecord>,
    /// Outcome buffer for the in-flight round, indexed by block.
    pending: Vec<Option<JobOutcome>>,
    outstanding: usize,
    round_started: Option<Instant>,
    labels: Option<LabelMap>,
    inertia: f64,
    /// Label-sink byte budget; `None` keeps the dense in-memory map.
    label_budget: Option<u64>,
}

impl GlobalState {
    /// Set up a run from the shared init draw (identical to the
    /// sequential baseline's). `fixed_iters` runs exactly that many step
    /// rounds with no convergence test. `label_budget` sizes the final
    /// [`LabelSink`] (`None` = dense, the seed behaviour).
    pub fn new(
        plan: Arc<BlockPlan>,
        channels: usize,
        cfg: &KMeansConfig,
        fixed_iters: Option<usize>,
        init_centroids: Vec<f32>,
        label_budget: Option<u64>,
    ) -> GlobalState {
        assert_eq!(init_centroids.len(), cfg.k * channels, "init centroid table size");
        let max_rounds = fixed_iters.unwrap_or(cfg.max_iters);
        let blocks = plan.len();
        GlobalState {
            plan,
            channels,
            k: cfg.k,
            tol: if fixed_iters.is_some() { 0.0 } else { cfg.tol },
            max_rounds,
            fixed: fixed_iters.is_some(),
            phase: if max_rounds == 0 {
                GlobalPhase::Assign
            } else {
                GlobalPhase::Step
            },
            centroids: init_centroids,
            drift: None,
            iterations: 0,
            converged: false,
            inertia_trace: Vec::new(),
            rounds: Vec::new(),
            pending: (0..blocks).map(|_| None).collect(),
            outstanding: 0,
            round_started: None,
            labels: None,
            inertia: 0.0,
            label_budget,
        }
    }

    pub fn phase(&self) -> GlobalPhase {
        self.phase
    }

    pub fn done(&self) -> bool {
        self.phase == GlobalPhase::Done
    }

    /// Blocks still missing from the in-flight round.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Build the current round's jobs, tagged with `job`. One job per
    /// block; the round clock starts now.
    pub fn start_round(&mut self, job: JobId) -> Vec<Job> {
        assert_eq!(self.outstanding, 0, "round already in flight");
        assert!(!self.done(), "run already complete");
        self.round_started = Some(Instant::now());
        self.outstanding = self.plan.len();
        let cen = Arc::new(self.centroids.clone());
        let round = self.iterations as u64;
        (0..self.plan.len())
            .map(|block| Job {
                job,
                block,
                round,
                payload: match self.phase {
                    GlobalPhase::Step => JobPayload::Step {
                        centroids: Arc::clone(&cen),
                        drift: self.drift.clone(),
                    },
                    GlobalPhase::Assign => JobPayload::Assign {
                        centroids: Arc::clone(&cen),
                        drift: self.drift.clone(),
                    },
                    GlobalPhase::Done => unreachable!("checked above"),
                },
            })
            .collect()
    }

    /// Buffer one outcome of the in-flight round. Returns `true` when
    /// the round is complete (every block arrived) and
    /// [`GlobalState::finish_round`] should run.
    pub fn absorb(&mut self, outcome: JobOutcome) -> Result<bool> {
        ensure!(
            outcome.block < self.pending.len(),
            "block {} outside plan ({} blocks)",
            outcome.block,
            self.pending.len()
        );
        ensure!(
            outcome.round == self.iterations as u64,
            "stale outcome: round {} but job is at round {}",
            outcome.round,
            self.iterations
        );
        ensure!(
            self.pending[outcome.block].is_none(),
            "duplicate outcome for block {}",
            outcome.block
        );
        ensure!(self.outstanding > 0, "no round in flight");
        self.pending[outcome.block] = Some(outcome);
        self.outstanding -= 1;
        Ok(self.outstanding == 0)
    }

    /// Whether the in-flight round still needs this outcome. `false`
    /// for a duplicate of an already-absorbed block or a stale round —
    /// exactly the copies speculative re-execution produces, which the
    /// caller discards instead of feeding to [`GlobalState::absorb`].
    pub fn wants(&self, outcome: &JobOutcome) -> bool {
        outcome.block < self.pending.len()
            && outcome.round == self.iterations as u64
            && self.outstanding > 0
            && self.pending[outcome.block].is_none()
    }

    /// Whether `block` is still missing from the in-flight round (a
    /// failure for an already-absorbed block is a losing twin's, not a
    /// round-stopper).
    pub fn block_pending(&self, block: usize) -> bool {
        self.outstanding > 0
            && block < self.pending.len()
            && self.pending[block].is_none()
    }

    /// Reduce the completed round in block order and advance the phase.
    pub fn finish_round(&mut self) -> Result<()> {
        assert_eq!(self.outstanding, 0, "round still in flight");
        let wall_secs = self
            .round_started
            .take()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        match self.phase {
            GlobalPhase::Step => self.finish_step_round(wall_secs),
            GlobalPhase::Assign => self.finish_assign_round(wall_secs),
            GlobalPhase::Done => bail!("run already complete"),
        }
    }

    fn finish_step_round(&mut self, wall_secs: f64) -> Result<()> {
        let mut merged = StepAccum::zeros(self.k, self.channels);
        let mut costs = Vec::with_capacity(self.pending.len());
        for slot in &mut self.pending {
            let o = slot.take().expect("round complete");
            let JobResult::Step { accum } = &o.result else {
                bail!("unexpected result kind in step round");
            };
            merged.merge(accum);
            costs.push(BlockCost::from_outcome(&o));
        }
        self.rounds.push(RoundRecord {
            kind: RoundKind::Step,
            wall_secs,
            costs,
        });
        self.inertia_trace.push(merged.inertia);
        let prev = self.centroids.clone();
        let moved = math::update_centroids(&merged, &mut self.centroids, self.tol);
        self.drift = Some(Arc::new(drift_between(
            &prev,
            &self.centroids,
            self.k,
            self.channels,
        )));
        self.iterations += 1;
        if !self.fixed && !moved {
            self.converged = true;
            self.phase = GlobalPhase::Assign;
        } else if self.iterations >= self.max_rounds {
            self.phase = GlobalPhase::Assign;
        }
        Ok(())
    }

    fn finish_assign_round(&mut self, wall_secs: f64) -> Result<()> {
        let mut sink =
            LabelSink::new(self.plan.height(), self.plan.width(), self.label_budget)?;
        let mut inertia = 0.0;
        let mut costs = Vec::with_capacity(self.pending.len());
        for slot in &mut self.pending {
            let o = slot.take().expect("round complete");
            let JobResult::Assign {
                labels,
                inertia: block_inertia,
            } = &o.result
            else {
                bail!("unexpected result kind in assign round");
            };
            sink.place(self.plan.region(o.block), labels)?;
            inertia += block_inertia;
            costs.push(BlockCost::from_outcome(&o));
        }
        self.rounds.push(RoundRecord {
            kind: RoundKind::Assign,
            wall_secs,
            costs,
        });
        self.labels = Some(sink.finish()?);
        self.inertia = inertia;
        self.phase = GlobalPhase::Done;
        Ok(())
    }

    /// Snapshot the round-boundary state as a checkpoint payload.
    /// Call only between rounds (nothing outstanding, not yet done):
    /// the per-block completion bitmap is all-ones at a boundary, and
    /// the label cursor is zero because labels only materialize in the
    /// final assign round.
    pub fn snapshot(&self, fingerprint: u64) -> Checkpoint {
        assert_eq!(self.outstanding, 0, "snapshot mid-round");
        assert!(!self.done(), "nothing to resume after Done");
        Checkpoint {
            fingerprint,
            iterations: self.iterations as u64,
            phase: match self.phase {
                GlobalPhase::Step => CheckpointPhase::Step,
                GlobalPhase::Assign => CheckpointPhase::Assign,
                GlobalPhase::Done => unreachable!("guarded above"),
            },
            converged: self.converged,
            centroids: self.centroids.clone(),
            inertia_trace: self.inertia_trace.clone(),
            blocks_done: vec![true; self.plan.len()],
            label_cursor: 0,
        }
    }

    /// Like [`GlobalState::snapshot`], but callable **mid-round**: any
    /// partial progress of the in-flight round is discarded and the
    /// checkpoint captures the last completed boundary (the centroids
    /// shipped with this round's jobs), so a resumed run re-executes
    /// the interrupted round from scratch — bit-identically, because
    /// each round is a pure function of those centroids. `None` once
    /// the run is done (nothing left to resume). This is the drain
    /// path's snapshot: a deadline can land with blocks still out.
    pub fn boundary_snapshot(&self, fingerprint: u64) -> Option<Checkpoint> {
        if self.done() {
            return None;
        }
        Some(Checkpoint {
            fingerprint,
            iterations: self.iterations as u64,
            phase: match self.phase {
                GlobalPhase::Step => CheckpointPhase::Step,
                GlobalPhase::Assign => CheckpointPhase::Assign,
                GlobalPhase::Done => unreachable!("guarded above"),
            },
            converged: self.converged,
            centroids: self.centroids.clone(),
            inertia_trace: self.inertia_trace.clone(),
            blocks_done: vec![true; self.plan.len()],
            label_cursor: 0,
        })
    }

    /// Rewind a freshly initialized run to a checkpointed boundary.
    /// The init draw is discarded and the checkpointed centroids,
    /// round index, convergence state, and inertia trace take over;
    /// `drift` restarts at `None`, which only makes the first resumed
    /// round prune nothing — Hamerly bounds are an optimization with
    /// exact semantics, so every downstream value is bit-identical to
    /// the uninterrupted run's.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        ensure!(
            self.outstanding == 0 && self.iterations == 0 && self.rounds.is_empty(),
            "restore requires a freshly initialized run"
        );
        ensure!(
            ck.centroids.len() == self.k * self.channels,
            "checkpoint has {} centroid values, this run needs {} (k={} × channels={})",
            ck.centroids.len(),
            self.k * self.channels,
            self.k,
            self.channels
        );
        ensure!(
            ck.blocks_done.len() == self.plan.len(),
            "checkpoint covers {} blocks, this plan has {}",
            ck.blocks_done.len(),
            self.plan.len()
        );
        ensure!(
            ck.blocks_done.iter().all(|&b| b) && ck.label_cursor == 0,
            "mid-round checkpoints are not resumable by this build"
        );
        ensure!(
            ck.iterations as usize <= self.max_rounds,
            "checkpoint at round {} exceeds this run's cap of {}",
            ck.iterations,
            self.max_rounds
        );
        self.centroids = ck.centroids.clone();
        self.iterations = ck.iterations as usize;
        self.converged = ck.converged;
        self.inertia_trace = ck.inertia_trace.clone();
        self.drift = None;
        self.phase = match ck.phase {
            CheckpointPhase::Step => GlobalPhase::Step,
            CheckpointPhase::Assign => GlobalPhase::Assign,
        };
        Ok(())
    }

    /// Take the finished output. Errors if the run is not done.
    pub fn into_output(self) -> Result<GlobalOutput> {
        ensure!(self.done(), "global run not complete");
        Ok(GlobalOutput {
            labels: self.labels.expect("done implies labels"),
            centroids: self.centroids,
            inertia: self.inertia,
            inertia_trace: self.inertia_trace,
            iterations: self.iterations,
            converged: self.converged,
            rounds: self.rounds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::coordinator::messages::SOLO_JOB;

    fn state(blocks_side: usize, fixed: Option<usize>) -> GlobalState {
        let plan = Arc::new(BlockPlan::new(12, 12, BlockShape::Square { side: blocks_side }));
        GlobalState::new(
            plan,
            1,
            &KMeansConfig {
                k: 2,
                max_iters: 5,
                ..Default::default()
            },
            fixed,
            vec![0.0, 10.0],
            None,
        )
    }

    #[test]
    fn zero_fixed_iters_goes_straight_to_assign() {
        let st = state(6, Some(0));
        assert_eq!(st.phase(), GlobalPhase::Assign);
    }

    #[test]
    fn start_round_emits_one_job_per_block() {
        let mut st = state(6, None);
        let jobs = st.start_round(SOLO_JOB);
        assert_eq!(jobs.len(), 4);
        assert!(jobs
            .iter()
            .enumerate()
            .all(|(i, j)| j.block == i && j.round == 0 && j.job == SOLO_JOB));
        assert_eq!(st.outstanding(), 4);
    }

    #[test]
    fn snapshot_restore_round_trips_the_boundary_state() {
        let mut st = state(6, Some(3));
        // Drive one full step round by hand.
        let jobs = st.start_round(SOLO_JOB);
        for j in jobs {
            let mut accum = StepAccum::zeros(2, 1);
            accum.counts = vec![3, 1];
            accum.sums = vec![3.0 * (j.block as f64 + 1.0), 10.0];
            accum.inertia = 1.5;
            st.absorb(JobOutcome {
                job: SOLO_JOB,
                block: j.block,
                round: 0,
                worker: 0,
                timing: Default::default(),
                result: JobResult::Step { accum },
            })
            .unwrap();
        }
        st.finish_round().unwrap();
        let ck = st.snapshot(42);
        assert_eq!(ck.iterations, 1);
        assert_eq!(ck.blocks_done, vec![true; 4]);
        assert_eq!(ck.label_cursor, 0);
        // Restore into a fresh machine: centroids/trace/round carried
        // over exactly, different init draw discarded.
        let mut fresh = state(6, Some(3));
        fresh.restore(&ck).unwrap();
        let ck2 = fresh.snapshot(42);
        assert_eq!(ck2, ck);
        // Restore rejects a mismatched geometry cleanly.
        let mut wrong = state(12, Some(3)); // one block, not four
        let err = wrong.restore(&ck).unwrap_err().to_string();
        assert!(err.contains("blocks"), "{err}");
    }

    #[test]
    fn absorb_rejects_stale_and_duplicate_outcomes() {
        let mut st = state(12, None); // one block
        let jobs = st.start_round(SOLO_JOB);
        assert_eq!(jobs.len(), 1);
        let ok = JobOutcome {
            job: SOLO_JOB,
            block: 0,
            round: 0,
            worker: 0,
            timing: Default::default(),
            result: JobResult::Step {
                accum: StepAccum::zeros(2, 1),
            },
        };
        let stale = JobOutcome {
            round: 9,
            ..ok.clone()
        };
        assert!(st.absorb(stale).is_err());
        assert!(st.absorb(ok.clone()).unwrap());
        assert!(st.absorb(ok).is_err(), "duplicate must be rejected");
    }
}
