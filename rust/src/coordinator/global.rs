//! Global-mode parallel K-Means: one clustering over the whole image.
//!
//! Each Lloyd iteration is a round: workers produce per-block partial
//! accumulations at the current centroids; the leader merges them
//! (associative f64 reduction), updates centroids, and tests convergence.
//! Because the merged accumulation is *identical* to the sequential
//! baseline's whole-image pass, global mode reproduces `SeqKMeans`
//! exactly — same labels, same centroids, same iteration count — which
//! the integration tests assert. Parallelism changes time, not results.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::messages::{Job, JobPayload, JobResult};
use super::pool::WorkerPool;
use super::{BlockCost, RoundKind, RoundRecord};
use crate::blocks::{BlockPlan, LabelAssembler};
use crate::kmeans::kernel::{drift_between, CentroidDrift};
use crate::kmeans::math::{self, StepAccum};
use crate::kmeans::KMeansConfig;
use crate::metrics::time_it;

/// Outcome of the iterate phase.
pub struct GlobalIterateResult {
    pub centroids: Vec<f32>,
    pub iterations: usize,
    pub converged: bool,
    /// Inertia measured at the centroids *entering* each step round
    /// (monotone non-increasing — a tested Lloyd invariant).
    pub inertia_trace: Vec<f64>,
    pub rounds: Vec<RoundRecord>,
    /// Movement of the final centroid update (`None` if no round ran).
    /// The fused assign round uses it to advance per-block bounds from
    /// the last step round's centroids to the final ones.
    pub drift: Option<Arc<CentroidDrift>>,
}

/// Run Lloyd iterations through the pool until convergence/`max_iters`
/// (or exactly `fixed_iters` when given, with no convergence test).
pub fn iterate(
    pool: &WorkerPool,
    plan: &BlockPlan,
    channels: usize,
    cfg: &KMeansConfig,
    fixed_iters: Option<usize>,
    mut centroids: Vec<f32>,
) -> Result<GlobalIterateResult> {
    let mut rounds = Vec::new();
    let mut iterations = 0;
    let mut converged = false;
    let mut inertia_trace = Vec::new();
    let max = fixed_iters.unwrap_or(cfg.max_iters);
    let tol = if fixed_iters.is_some() { 0.0 } else { cfg.tol };
    // Per-centroid movement of the update that produced the *current*
    // centroids; shipped with each round so pruned workers can advance
    // their block-local bounds. `None` on round 0 (no previous update).
    let mut drift: Option<Arc<CentroidDrift>> = None;
    for iter in 0..max {
        iterations += 1;
        let cen = Arc::new(centroids.clone());
        let jobs: Vec<Job> = (0..plan.len())
            .map(|b| Job {
                block: b,
                round: iter as u64,
                payload: JobPayload::Step {
                    centroids: Arc::clone(&cen),
                    drift: drift.clone(),
                },
            })
            .collect();
        let (outcomes, wall) = {
            let (r, secs) = time_it(|| pool.run_round(jobs));
            (r?, secs)
        };
        let mut merged = StepAccum::zeros(cfg.k, channels);
        let mut costs = Vec::with_capacity(outcomes.len());
        for o in &outcomes {
            let JobResult::Step { accum } = &o.result else {
                bail!("unexpected result kind in step round");
            };
            merged.merge(accum);
            costs.push(BlockCost::from_outcome(o));
        }
        rounds.push(RoundRecord {
            kind: RoundKind::Step,
            wall_secs: wall,
            costs,
        });
        inertia_trace.push(merged.inertia);
        let prev = centroids.clone();
        let moved = math::update_centroids(&merged, &mut centroids, tol);
        drift = Some(Arc::new(drift_between(&prev, &centroids, cfg.k, channels)));
        if fixed_iters.is_none() && !moved {
            converged = true;
            break;
        }
    }
    Ok(GlobalIterateResult {
        centroids,
        iterations,
        converged,
        inertia_trace,
        rounds,
        drift,
    })
}

/// Final assignment round: label every block at `centroids`, assemble
/// the full map. `round` must be the number of completed step rounds
/// (so workers can tell their bounds continue exactly into this round)
/// and `drift` the movement of the final centroid update; fused-kernel
/// workers then label from their bounds instead of a full scan.
/// Returns `(labels, inertia, round_record)`.
pub fn assign(
    pool: &WorkerPool,
    plan: &BlockPlan,
    centroids: &[f32],
    round: u64,
    drift: Option<Arc<CentroidDrift>>,
) -> Result<(Vec<u32>, f64, RoundRecord)> {
    let cen = Arc::new(centroids.to_vec());
    let jobs: Vec<Job> = (0..plan.len())
        .map(|b| Job {
            block: b,
            round,
            payload: JobPayload::Assign {
                centroids: Arc::clone(&cen),
                drift: drift.clone(),
            },
        })
        .collect();
    let (outcomes, wall) = {
        let (r, secs) = time_it(|| pool.run_round(jobs));
        (r?, secs)
    };
    let mut assembler = LabelAssembler::new(plan.height(), plan.width());
    let mut inertia = 0.0;
    let mut costs = Vec::with_capacity(outcomes.len());
    for o in &outcomes {
        let JobResult::Assign {
            labels,
            inertia: block_inertia,
        } = &o.result
        else {
            bail!("unexpected result kind in assign round");
        };
        assembler.place(plan.region(o.block), labels)?;
        inertia += block_inertia;
        costs.push(BlockCost::from_outcome(o));
    }
    let labels = assembler.finish()?;
    Ok((
        labels,
        inertia,
        RoundRecord {
            kind: RoundKind::Assign,
            wall_secs: wall,
            costs,
        },
    ))
}
