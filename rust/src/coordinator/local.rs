//! Local-mode block processing: independent K-Means per block.
//!
//! This is what the paper's `blockproc(@kmeans)` literally computes:
//! every block is clustered on its own. Block-local label spaces are
//! arbitrary, so the leader **harmonizes** them afterwards: it runs a
//! count-weighted K-Means over the union of block centroids (seeded by
//! the global init), then remaps every block's local labels through the
//! nearest harmonized centre. The output label map is then globally
//! consistent — visually comparable to the sequential result (Figs 5/7
//! vs 4/6) — while each block's clustering stayed embarrassingly
//! parallel (no per-iteration barrier at all).
//!
//! [`LocalState`] is the single-round state machine: one Local job per
//! block, outcomes buffered per block as they stream in (any order —
//! multi-job leaders interleave), harmonization and assembly in block
//! order at the end, so service runs reduce exactly like solo runs.
//!
//! Fault tolerance: local mode participates fully in block **retry** —
//! each Local job is a pure function of the shipped init centroids, so
//! a re-queued block recomputes bit-identically on any worker. It does
//! **not** participate in checkpoint/resume: the whole run is one round,
//! so there is no boundary to snapshot ([`super::RunMachine::snapshot`]
//! returns `None` here and resume requests are rejected).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use super::messages::{Job, JobId, JobOutcome, JobPayload, JobResult};
use super::{BlockCost, RoundKind, RoundRecord};
use crate::blocks::{BlockPlan, LabelMap, LabelSink};
use crate::kmeans::math::sqdist;

/// Completed output of a local-mode run.
#[derive(Debug)]
pub struct LocalOutput {
    pub labels: LabelMap,
    /// Harmonized global centroids.
    pub centroids: Vec<f32>,
    /// Sum of per-block inertias (w.r.t. each block's own centroids).
    pub inertia: f64,
    pub rounds: Vec<RoundRecord>,
}

/// One job's local-mode state: a single round of per-block clusterings
/// followed by harmonization.
pub struct LocalState {
    plan: Arc<BlockPlan>,
    channels: usize,
    k: usize,
    init: Arc<Vec<f32>>,
    pending: Vec<Option<JobOutcome>>,
    outstanding: usize,
    round_started: Option<Instant>,
    output: Option<LocalOutput>,
    /// Label-sink byte budget; `None` keeps the dense in-memory map.
    label_budget: Option<u64>,
}

impl LocalState {
    pub fn new(
        plan: Arc<BlockPlan>,
        channels: usize,
        k: usize,
        init_centroids: Vec<f32>,
        label_budget: Option<u64>,
    ) -> LocalState {
        assert_eq!(init_centroids.len(), k * channels, "init centroid table size");
        let blocks = plan.len();
        LocalState {
            plan,
            channels,
            k,
            init: Arc::new(init_centroids),
            pending: (0..blocks).map(|_| None).collect(),
            outstanding: 0,
            round_started: None,
            output: None,
            label_budget,
        }
    }

    pub fn done(&self) -> bool {
        self.output.is_some()
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Build the round's jobs (one Local job per block), tagged `job`.
    pub fn start_round(&mut self, job: JobId) -> Vec<Job> {
        assert_eq!(self.outstanding, 0, "round already in flight");
        assert!(!self.done(), "run already complete");
        self.round_started = Some(Instant::now());
        self.outstanding = self.plan.len();
        (0..self.plan.len())
            .map(|block| Job {
                job,
                block,
                round: 0,
                payload: JobPayload::Local {
                    init: Arc::clone(&self.init),
                },
            })
            .collect()
    }

    /// Buffer one outcome. Returns `true` when every block has arrived.
    pub fn absorb(&mut self, outcome: JobOutcome) -> Result<bool> {
        ensure!(
            outcome.block < self.pending.len(),
            "block {} outside plan ({} blocks)",
            outcome.block,
            self.pending.len()
        );
        ensure!(
            self.pending[outcome.block].is_none(),
            "duplicate outcome for block {}",
            outcome.block
        );
        ensure!(self.outstanding > 0, "no round in flight");
        self.pending[outcome.block] = Some(outcome);
        self.outstanding -= 1;
        Ok(self.outstanding == 0)
    }

    /// Whether the (single) in-flight round still needs this outcome —
    /// see [`crate::coordinator::GlobalState::wants`].
    pub fn wants(&self, outcome: &JobOutcome) -> bool {
        outcome.block < self.pending.len()
            && self.outstanding > 0
            && self.pending[outcome.block].is_none()
    }

    /// Whether `block` is still missing from the in-flight round.
    pub fn block_pending(&self, block: usize) -> bool {
        self.outstanding > 0
            && block < self.pending.len()
            && self.pending[block].is_none()
    }

    /// Harmonize the completed round and assemble the label map.
    pub fn finish_round(&mut self) -> Result<()> {
        assert_eq!(self.outstanding, 0, "round still in flight");
        ensure!(!self.done(), "run already complete");
        let wall_secs = self
            .round_started
            .take()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);

        // Collect block centroids + weights in block order.
        let mut block_centroids: Vec<Vec<f32>> = Vec::with_capacity(self.pending.len());
        let mut block_counts: Vec<Vec<u64>> = Vec::with_capacity(self.pending.len());
        let mut inertia = 0.0;
        let mut costs = Vec::with_capacity(self.pending.len());
        for slot in &self.pending {
            let o = slot.as_ref().expect("round complete");
            let JobResult::Local {
                centroids,
                inertia: bi,
                counts,
                ..
            } = &o.result
            else {
                bail!("unexpected result kind in local round");
            };
            block_centroids.push(centroids.clone());
            block_counts.push(counts.clone());
            inertia += bi;
            costs.push(BlockCost::from_outcome(o));
        }

        // Harmonize: weighted K-Means over all block centroids, seeded at
        // the global init (so K stays K and empty centres keep a defined
        // spot).
        let global = harmonize_centroids(
            &block_centroids,
            &block_counts,
            &self.init,
            self.k,
            self.channels,
            10,
        );

        // Remap labels block by block and assemble.
        let mut sink =
            LabelSink::new(self.plan.height(), self.plan.width(), self.label_budget)?;
        for slot in &mut self.pending {
            let o = slot.take().expect("round complete");
            let JobResult::Local {
                labels, centroids, ..
            } = &o.result
            else {
                unreachable!("checked above");
            };
            let map = label_map(centroids, &global, self.k, self.channels);
            let remapped: Vec<u32> = labels.iter().map(|&l| map[l as usize]).collect();
            sink.place(self.plan.region(o.block), &remapped)?;
        }
        let labels = sink.finish()?;

        self.output = Some(LocalOutput {
            labels,
            centroids: global,
            inertia,
            rounds: vec![RoundRecord {
                kind: RoundKind::Local,
                wall_secs,
                costs,
            }],
        });
        Ok(())
    }

    /// Take the finished output. Errors if the run is not done.
    pub fn into_output(self) -> Result<LocalOutput> {
        self.output.ok_or_else(|| anyhow::anyhow!("local run not complete"))
    }
}

/// Weighted Lloyd over the union of block centroids. Points are the
/// `blocks×k` local centroids weighted by their member counts; seeds are
/// the global init centroids; empty harmonized centres keep their seed.
pub fn harmonize_centroids(
    block_centroids: &[Vec<f32>],
    block_counts: &[Vec<u64>],
    init: &[f32],
    k: usize,
    channels: usize,
    iters: usize,
) -> Vec<f32> {
    let mut centers = init.to_vec();
    assert_eq!(centers.len(), k * channels);
    for _ in 0..iters {
        let mut sums = vec![0.0f64; k * channels];
        let mut weights = vec![0.0f64; k];
        for (bc, cnts) in block_centroids.iter().zip(block_counts) {
            for (j, point) in bc.chunks_exact(channels).enumerate() {
                let w = cnts[j] as f64;
                if w == 0.0 {
                    continue; // empty local cluster carries no information
                }
                let g = nearest_center(point, &centers, k, channels);
                let base = g * channels;
                for (c, &v) in point.iter().enumerate() {
                    sums[base + c] += v as f64 * w;
                }
                weights[g] += w;
            }
        }
        let mut moved = false;
        for g in 0..k {
            if weights[g] == 0.0 {
                continue;
            }
            let base = g * channels;
            for c in 0..channels {
                let fresh = (sums[base + c] / weights[g]) as f32;
                if (fresh - centers[base + c]).abs() > 1e-6 {
                    moved = true;
                }
                centers[base + c] = fresh;
            }
        }
        if !moved {
            break;
        }
    }
    centers
}

/// For each local label `j`, the harmonized centre its centroid maps to.
pub fn label_map(local_centroids: &[f32], global: &[f32], k: usize, channels: usize) -> Vec<u32> {
    local_centroids
        .chunks_exact(channels)
        .map(|c| nearest_center(c, global, k, channels) as u32)
        .collect()
}

fn nearest_center(point: &[f32], centers: &[f32], k: usize, channels: usize) -> usize {
    let mut best = 0;
    let mut best_d = f32::INFINITY;
    for g in 0..k {
        let d = sqdist(point, &centers[g * channels..(g + 1) * channels]);
        if d < best_d {
            best_d = d;
            best = g;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harmonize_converges_to_weighted_means() {
        // two blocks, k=2, channels=1; block centroids cluster around 0 & 100
        let bc = vec![vec![1.0f32, 99.0], vec![3.0, 101.0]];
        let counts = vec![vec![10u64, 10], vec![30, 10]];
        let global = harmonize_centroids(&bc, &counts, &[0.0, 100.0], 2, 1, 20);
        // low centre: (1*10 + 3*30)/(40) = 2.5; high: (99*10+101*10)/20 = 100
        assert!((global[0] - 2.5).abs() < 1e-4, "{global:?}");
        assert!((global[1] - 100.0).abs() < 1e-4, "{global:?}");
    }

    #[test]
    fn empty_local_clusters_are_ignored() {
        let bc = vec![vec![5.0f32, 777.0]]; // second centroid has count 0
        let counts = vec![vec![4u64, 0]];
        let global = harmonize_centroids(&bc, &counts, &[0.0, 100.0], 2, 1, 10);
        assert!((global[0] - 5.0).abs() < 1e-4);
        assert_eq!(global[1], 100.0, "empty centre keeps its seed");
    }

    #[test]
    fn label_map_routes_to_nearest() {
        let local = vec![10.0f32, 90.0];
        let global = vec![0.0f32, 100.0];
        assert_eq!(label_map(&local, &global, 2, 1), vec![0, 1]);
        // swapped local order must swap the map
        let local = vec![90.0f32, 10.0];
        assert_eq!(label_map(&local, &global, 2, 1), vec![1, 0]);
    }
}
