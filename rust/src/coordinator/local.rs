//! Local-mode block processing: independent K-Means per block.
//!
//! This is what the paper's `blockproc(@kmeans)` literally computes:
//! every block is clustered on its own. Block-local label spaces are
//! arbitrary, so the leader **harmonizes** them afterwards: it runs a
//! count-weighted K-Means over the union of block centroids (seeded by
//! the global init), then remaps every block's local labels through the
//! nearest harmonized centre. The output label map is then globally
//! consistent — visually comparable to the sequential result (Figs 5/7
//! vs 4/6) — while each block's clustering stayed embarrassingly
//! parallel (no per-iteration barrier at all).

use std::sync::Arc;

use anyhow::{bail, Result};

use super::messages::{Job, JobPayload, JobResult};
use super::pool::WorkerPool;
use super::{BlockCost, RoundKind, RoundRecord};
use crate::blocks::{BlockPlan, LabelAssembler};
use crate::kmeans::math::sqdist;
use crate::metrics::time_it;

/// Result of the local-mode run.
pub struct LocalRunResult {
    pub labels: Vec<u32>,
    /// Harmonized global centroids.
    pub centroids: Vec<f32>,
    /// Sum of per-block inertias (w.r.t. each block's own centroids).
    pub inertia: f64,
    pub rounds: Vec<RoundRecord>,
}

/// Run one Local round over all blocks and harmonize.
pub fn run(
    pool: &WorkerPool,
    plan: &BlockPlan,
    channels: usize,
    k: usize,
    init_centroids: &[f32],
) -> Result<LocalRunResult> {
    let init = Arc::new(init_centroids.to_vec());
    let jobs: Vec<Job> = (0..plan.len())
        .map(|b| Job {
            block: b,
            round: 0,
            payload: JobPayload::Local {
                init: Arc::clone(&init),
            },
        })
        .collect();
    let (outcomes, wall) = {
        let (r, secs) = time_it(|| pool.run_round(jobs));
        (r?, secs)
    };

    // Collect block centroids + weights.
    let mut block_centroids: Vec<Vec<f32>> = Vec::with_capacity(outcomes.len());
    let mut block_counts: Vec<Vec<u64>> = Vec::with_capacity(outcomes.len());
    let mut inertia = 0.0;
    let mut costs = Vec::with_capacity(outcomes.len());
    for o in &outcomes {
        let JobResult::Local {
            centroids,
            inertia: bi,
            counts,
            ..
        } = &o.result
        else {
            bail!("unexpected result kind in local round");
        };
        block_centroids.push(centroids.clone());
        block_counts.push(counts.clone());
        inertia += bi;
        costs.push(BlockCost::from_outcome(o));
    }

    // Harmonize: weighted K-Means over all block centroids, seeded at the
    // global init (so K stays K and empty centres keep a defined spot).
    let global = harmonize_centroids(
        &block_centroids,
        &block_counts,
        init_centroids,
        k,
        channels,
        10,
    );

    // Remap labels block by block and assemble.
    let mut assembler = LabelAssembler::new(plan.height(), plan.width());
    for o in &outcomes {
        let JobResult::Local {
            labels, centroids, ..
        } = &o.result
        else {
            unreachable!("checked above");
        };
        let map = label_map(centroids, &global, k, channels);
        let remapped: Vec<u32> = labels.iter().map(|&l| map[l as usize]).collect();
        assembler.place(plan.region(o.block), &remapped)?;
    }
    let labels = assembler.finish()?;

    Ok(LocalRunResult {
        labels,
        centroids: global,
        inertia,
        rounds: vec![RoundRecord {
            kind: RoundKind::Local,
            wall_secs: wall,
            costs,
        }],
    })
}

/// Weighted Lloyd over the union of block centroids. Points are the
/// `blocks×k` local centroids weighted by their member counts; seeds are
/// the global init centroids; empty harmonized centres keep their seed.
pub fn harmonize_centroids(
    block_centroids: &[Vec<f32>],
    block_counts: &[Vec<u64>],
    init: &[f32],
    k: usize,
    channels: usize,
    iters: usize,
) -> Vec<f32> {
    let mut centers = init.to_vec();
    assert_eq!(centers.len(), k * channels);
    for _ in 0..iters {
        let mut sums = vec![0.0f64; k * channels];
        let mut weights = vec![0.0f64; k];
        for (bc, cnts) in block_centroids.iter().zip(block_counts) {
            for (j, point) in bc.chunks_exact(channels).enumerate() {
                let w = cnts[j] as f64;
                if w == 0.0 {
                    continue; // empty local cluster carries no information
                }
                let g = nearest_center(point, &centers, k, channels);
                let base = g * channels;
                for (c, &v) in point.iter().enumerate() {
                    sums[base + c] += v as f64 * w;
                }
                weights[g] += w;
            }
        }
        let mut moved = false;
        for g in 0..k {
            if weights[g] == 0.0 {
                continue;
            }
            let base = g * channels;
            for c in 0..channels {
                let fresh = (sums[base + c] / weights[g]) as f32;
                if (fresh - centers[base + c]).abs() > 1e-6 {
                    moved = true;
                }
                centers[base + c] = fresh;
            }
        }
        if !moved {
            break;
        }
    }
    centers
}

/// For each local label `j`, the harmonized centre its centroid maps to.
pub fn label_map(local_centroids: &[f32], global: &[f32], k: usize, channels: usize) -> Vec<u32> {
    local_centroids
        .chunks_exact(channels)
        .map(|c| nearest_center(c, global, k, channels) as u32)
        .collect()
}

fn nearest_center(point: &[f32], centers: &[f32], k: usize, channels: usize) -> usize {
    let mut best = 0;
    let mut best_d = f32::INFINITY;
    for g in 0..k {
        let d = sqdist(point, &centers[g * channels..(g + 1) * channels]);
        if d < best_d {
            best_d = d;
            best = g;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harmonize_converges_to_weighted_means() {
        // two blocks, k=2, channels=1; block centroids cluster around 0 & 100
        let bc = vec![vec![1.0f32, 99.0], vec![3.0, 101.0]];
        let counts = vec![vec![10u64, 10], vec![30, 10]];
        let global = harmonize_centroids(&bc, &counts, &[0.0, 100.0], 2, 1, 20);
        // low centre: (1*10 + 3*30)/(40) = 2.5; high: (99*10+101*10)/20 = 100
        assert!((global[0] - 2.5).abs() < 1e-4, "{global:?}");
        assert!((global[1] - 100.0).abs() < 1e-4, "{global:?}");
    }

    #[test]
    fn empty_local_clusters_are_ignored() {
        let bc = vec![vec![5.0f32, 777.0]]; // second centroid has count 0
        let counts = vec![vec![4u64, 0]];
        let global = harmonize_centroids(&bc, &counts, &[0.0, 100.0], 2, 1, 10);
        assert!((global[0] - 5.0).abs() < 1e-4);
        assert_eq!(global[1], 100.0, "empty centre keeps its seed");
    }

    #[test]
    fn label_map_routes_to_nearest() {
        let local = vec![10.0f32, 90.0];
        let global = vec![0.0f32, 100.0];
        assert_eq!(label_map(&local, &global, 2, 1), vec![0, 1]);
        // swapped local order must swap the map
        let local = vec![90.0f32, 10.0];
        assert_eq!(label_map(&local, &global, 2, 1), vec![1, 0]);
    }
}
