//! Worker thread: pulls tagged jobs, reads the block, runs the backend.
//!
//! Since the service layer landed, one worker serves **many concurrent
//! clustering jobs**: per-job contexts are looked up in a shared
//! [`ContextRegistry`], and all mutable worker state — compute backend,
//! block reader, pruned bounds — is keyed by [`JobId`] (bounds by
//! `(job, block)`) so interleaved jobs can never contaminate each
//! other. Decoded SoA tiles are keyed by `(content, block)` instead:
//! sweep variants over one image carry the same
//! [`WorkerContext::content`] id and share tiles (one decode for N
//! variants), while unrelated jobs keep distinct content ids and stay
//! isolated. A [`JobPayload::Retire`] message drops a finished job's
//! cached state; its `purge_content` says whether the shared tiles go
//! too (only when the last share-group member leaves).
//!
//! Two layers sit between the block source and the compute backend:
//!
//! - the **tile arena** ([`TileArena`]) — with [`TileLayout::Soa`], a
//!   block's pixels are read once per job, deinterleaved into a planar
//!   [`SoaTile`], and reused across every Lloyd round (the seed re-read
//!   the whole strip span per block per round);
//! - the **prefetcher** — with `prefetch` enabled, each (worker, job)
//!   pair gets a sidecar thread with its own reader (dropped on
//!   `Retire`) that fills the *next* queued block's pixels while the
//!   current block computes (double buffering); same-job successors
//!   are issued after the current block's read, cross-job successors
//!   before dispatch on their own job's sidecar. The peek is a hint: a
//!   mispredicted fill is banked or dropped, never used for the wrong
//!   block and never waited on.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::messages::{BlockTiming, Job, JobError, JobId, JobOutcome, JobPayload, JobResult};
use super::queue::JobQueue;
use crate::blocks::BlockPlan;
use crate::image::Raster;
use crate::kmeans::kernel::{CentroidDrift, KernelChoice, PrunedState};
use crate::kmeans::tile::{SoaTile, TileArena, TileLayout};
use crate::plan::ExecPlan;
use crate::resilience::{FaultKind, FaultPlan, Watchdog};
use crate::runtime::BackendSpec;
use crate::stripstore::{StripReader, StripStore};

/// Where workers get block pixels from.
#[derive(Clone)]
pub enum BlockSource {
    /// Crop directly from the shared in-memory raster.
    Direct(Arc<Raster>),
    /// Read via a strip store (whole-strip reads, counted) — the
    /// `blockproc` I/O model.
    Strips(Arc<StripStore>),
}

/// Everything a worker needs to process one job's blocks. One instance
/// per clustering job, shared by all workers through the pool's
/// [`ContextRegistry`].
#[derive(Clone)]
pub struct WorkerContext {
    pub plan: Arc<BlockPlan>,
    pub source: BlockSource,
    pub backend: BackendSpec,
    /// Deterministic fault injection: which block fails, how, and on
    /// which visits (tests, the resilience bench, CI fault drills).
    pub fault: Option<FaultPlan>,
    /// Hint for backend warmup: will this job use per-block local mode?
    pub local_mode: bool,
    /// The job's resolved execution plan — workers consume the kernel,
    /// layout, arena budget, and prefetch knobs from here (the shape
    /// already materialized into `plan`, the worker count into the
    /// pool). Kernel/layout choices are bit-identical; see
    /// [`crate::kmeans::kernel`] and [`crate::kmeans::tile`].
    pub exec: ExecPlan,
    /// *Content id* for the tile arena: jobs reading the same pixels
    /// (sweep variants over one image) share it, so a block decoded +
    /// deinterleaved by one variant is a hit for every sibling —
    /// `(content, block)` keys the arena where the seed keyed
    /// `(job, block)`. Unshared jobs use their own job id (the solo
    /// coordinator uses [`super::messages::SOLO_JOB`]), which restores
    /// the seed's exact keying. Tiles are immutable once inserted, so
    /// sharing is value-safe; pruning state stays keyed by job.
    pub content: u64,
}

impl WorkerContext {
    /// Channel count of the underlying imagery.
    pub fn plan_channels(&self) -> usize {
        match &self.source {
            BlockSource::Direct(r) => r.channels(),
            BlockSource::Strips(s) => s.channels(),
        }
    }
}

/// Shared map of job id → per-job worker context. The leader registers a
/// context before submitting any of the job's blocks and removes it when
/// the job retires; workers resolve contexts lazily on first touch.
#[derive(Default)]
pub struct ContextRegistry {
    inner: RwLock<HashMap<JobId, Arc<WorkerContext>>>,
}

impl ContextRegistry {
    pub fn new() -> ContextRegistry {
        ContextRegistry::default()
    }

    /// Register (or replace) the context for `job`. Returns the number
    /// of jobs now registered.
    pub fn register(&self, job: JobId, ctx: Arc<WorkerContext>) -> usize {
        let mut map = self.inner.write().unwrap();
        map.insert(job, ctx);
        map.len()
    }

    pub fn remove(&self, job: JobId) {
        self.inner.write().unwrap().remove(&job);
    }

    pub fn get(&self, job: JobId) -> Option<Arc<WorkerContext>> {
        self.inner.read().unwrap().get(&job).cloned()
    }

    /// Number of currently registered jobs.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-(job, block) pruning state a worker carries across rounds.
/// `last_round` records the round whose centroids the bounds describe; a
/// job whose drift does not continue exactly from that round re-seeds
/// the bounds with a full scan (dynamic scheduling can migrate blocks
/// between workers, which must never change results).
#[derive(Default)]
struct BlockPrune {
    state: PrunedState,
    last_round: Option<u64>,
}

impl BlockPrune {
    /// The shipped drift is usable iff it advances this state by exactly
    /// one round.
    fn usable_drift<'d>(
        &self,
        drift: &'d Option<Arc<CentroidDrift>>,
        round: u64,
    ) -> Option<&'d CentroidDrift> {
        match (drift, self.last_round) {
            (Some(d), Some(r)) if r.checked_add(1) == Some(round) => Some(d.as_ref()),
            _ => None,
        }
    }
}

/// Drop pruning state of `job` that cannot continue into `round` (its
/// block migrated to another worker or skipped a round — it would
/// re-seed anyway). Other jobs' entries are untouched: eviction is keyed
/// by (job, block), so an interleaved neighbour's warm bounds survive.
/// Under a static schedule every owned block sits at `round` or
/// `round - 1` and is kept; under a dynamic schedule a migrated-away
/// block's orphaned state (20 bytes/pixel) is reclaimed within a round
/// instead of accumulating for the life of the pool.
fn evict_stale(prune: &mut HashMap<(JobId, usize), BlockPrune>, job: JobId, round: u64) {
    prune.retain(|(j, _), e| {
        *j != job || e.last_round.is_some_and(|r| r.saturating_add(1) >= round)
    });
}

/// Per-worker block reader (owns file handles / scratch).
enum Reader {
    Direct(Arc<Raster>),
    Strips(Box<StripReader>),
}

impl Reader {
    fn read(&mut self, plan: &BlockPlan, block: usize, buf: &mut Vec<f32>) -> Result<()> {
        let region = plan.region(block);
        match self {
            Reader::Direct(raster) => {
                raster.crop_into(region, buf);
                Ok(())
            }
            Reader::Strips(reader) => reader.read_block(region, buf),
        }
    }
}

fn build_reader(worker_id: usize, source: &BlockSource) -> Result<Reader> {
    Ok(match source {
        BlockSource::Direct(r) => Reader::Direct(Arc::clone(r)),
        BlockSource::Strips(s) => Reader::Strips(Box::new(
            s.reader()
                .with_context(|| format!("worker {worker_id}: open reader"))?,
        )),
    })
}

/// One worker's read-ahead slot for one job: a sidecar thread with its
/// own reader (own file handle, shared access counters) that fills the
/// next block's interleaved pixels while the worker computes. At most
/// one request is outstanding; a response for a block the worker no
/// longer wants is dropped (the peek that issued it was a hint).
struct Prefetcher {
    req: Option<Sender<usize>>,
    resp: Receiver<(usize, Result<Vec<f32>>)>,
    pending: Option<usize>,
    ready: Option<(usize, Vec<f32>)>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    fn spawn(worker_id: usize, ctx: &WorkerContext) -> Result<Prefetcher> {
        let mut reader = build_reader(worker_id, &ctx.source)?;
        let plan = Arc::clone(&ctx.plan);
        let (req_tx, req_rx) = channel::<usize>();
        let (resp_tx, resp_rx) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("blockms-prefetch-{worker_id}"))
            .spawn(move || {
                while let Ok(block) = req_rx.recv() {
                    let mut buf = Vec::new();
                    let result = reader.read(&plan, block, &mut buf).map(|()| buf);
                    if resp_tx.send((block, result)).is_err() {
                        return; // worker gone
                    }
                }
            })
            .context("spawn prefetch thread")?;
        Ok(Prefetcher {
            req: Some(req_tx),
            resp: resp_rx,
            pending: None,
            ready: None,
            handle: Some(handle),
        })
    }

    /// Ask for `block` unless a fill is already in flight or banked.
    fn issue(&mut self, block: usize) {
        if self.pending.is_some() {
            return;
        }
        if matches!(&self.ready, Some((b, _)) if *b == block) {
            return;
        }
        if let Some(req) = &self.req {
            if req.send(block).is_ok() {
                self.pending = Some(block);
            }
        }
    }

    /// Take the prefetched pixels for `block`; `None` means the caller
    /// must read synchronously. Blocks **only** when the in-flight fill
    /// is for exactly this block — a mispredicted fill is drained
    /// without waiting (banked if already complete, left running
    /// otherwise), so a bad peek never serializes two reads on the
    /// worker's critical path.
    fn take(&mut self, block: usize) -> Option<Result<Vec<f32>>> {
        // Bank whatever has completed, without waiting.
        if self.pending.is_some() {
            match self.resp.try_recv() {
                Ok((b, result)) => {
                    self.pending = None;
                    // Errors for a block we may not even want are
                    // dropped; the sync read will surface them if real.
                    if let Ok(buf) = result {
                        self.ready = Some((b, buf));
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {}
                Err(std::sync::mpsc::TryRecvError::Disconnected) => self.pending = None,
            }
        }
        if let Some((b, _)) = &self.ready {
            if *b == block {
                return self.ready.take().map(|(_, buf)| Ok(buf));
            }
            // Mispredicted for now, but block contents are immutable
            // within a job: the banked fill stays valid and is kept
            // until taken or replaced by a newer completion.
        }
        // Wait only for a fill of exactly this block.
        if self.pending == Some(block) {
            match self.resp.recv() {
                Ok((b, result)) => {
                    self.pending = None;
                    debug_assert_eq!(b, block, "one outstanding request");
                    if b == block {
                        return Some(result);
                    }
                }
                Err(_) => self.pending = None, // thread died; fall back
            }
        }
        None
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        self.req.take(); // closes the request channel; thread exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One job's lazily-built worker-local machinery: the compute backend
/// (PJRT client or native math), the block reader (own file handle),
/// and the optional read-ahead sidecar.
struct JobEngine {
    ctx: Arc<WorkerContext>,
    backend: Box<dyn crate::runtime::ComputeBackend>,
    reader: Reader,
    prefetch: Option<Prefetcher>,
}

impl JobEngine {
    fn build(worker_id: usize, ctx: Arc<WorkerContext>) -> Result<JobEngine> {
        let backend = ctx
            .backend
            .build()
            .with_context(|| format!("worker {worker_id}: backend init"))?;
        let reader = build_reader(worker_id, &ctx.source)?;
        let prefetch = if ctx.exec.prefetch {
            Some(Prefetcher::spawn(worker_id, &ctx)?)
        } else {
            None
        };
        Ok(JobEngine {
            ctx,
            backend,
            reader,
            prefetch,
        })
    }

    /// Read `block`'s interleaved pixels, preferring a completed
    /// prefetch over a synchronous read.
    fn read_pixels(&mut self, block: usize, buf: &mut Vec<f32>) -> Result<()> {
        if let Some(pf) = self.prefetch.as_mut() {
            if let Some(result) = pf.take(block) {
                *buf = result?;
                return Ok(());
            }
        }
        self.reader.read(&self.ctx.plan, block, buf)
    }
}

/// Render a panic payload as the human-readable message it carried.
pub(super) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Worker main loop. Runs on its own thread until the queue closes.
/// Every job message except [`JobPayload::Retire`] produces exactly one
/// message on `results` (Ok or Err), so the leader can count responses
/// without tracking worker liveness.
///
/// Fault containment: each dispatch runs under [`std::panic::catch_unwind`],
/// so a panicking block (a bug in a kernel, a poisoned tile, an injected
/// [`FaultKind::Panic`]) is reported as a [`JobError`] carrying the panic
/// message instead of silently killing the thread and hanging the round.
/// On *any* per-block failure the worker evicts its own state for that
/// `(job, block)` — the Hamerly bounds and arena tile may have been
/// half-mutated when the failure struck, and a retry must re-seed from
/// scratch exactly like a first visit (that re-seed is bit-identical;
/// see [`crate::resilience`]). A panic additionally drops the whole
/// job's engine on this worker: its backend/reader state is not
/// trustworthy mid-unwind, and rebuilding it is side-effect free.
pub fn worker_main(
    worker_id: usize,
    registry: Arc<ContextRegistry>,
    queue: Arc<JobQueue>,
    results: Sender<Result<JobOutcome, JobError>>,
    watchdog: Arc<Watchdog>,
) {
    let mut engines: HashMap<JobId, JobEngine> = HashMap::new();
    let mut px_buf: Vec<f32> = Vec::new();
    let mut prune: HashMap<(JobId, usize), BlockPrune> = HashMap::new();
    let mut arena = TileArena::new(0); // budget set from the filling job's context
    while let Some(job) = queue.pop(worker_id) {
        if let JobPayload::Retire { purge_content } = job.payload {
            engines.remove(&job.job);
            prune.retain(|(j, _), _| *j != job.job);
            // Arena tiles are keyed by *content*, which share-group
            // siblings may still be using — the leader tells us when
            // the last member leaves (None = keep shared tiles hot).
            if let Some(content) = purge_content {
                arena.purge_job(content);
            }
            continue;
        }
        // Heartbeat: stamp real block work only. Pings are excluded —
        // backend warmup (PJRT client build) legitimately takes far
        // longer than any block, and warmup has its own bounded wait.
        let stamped = matches!(
            job.payload,
            JobPayload::Step { .. } | JobPayload::Assign { .. } | JobPayload::Local { .. }
        );
        if stamped {
            watchdog.begin(worker_id, job.job, job.block, job.round);
        }
        // AssertUnwindSafe is sound here: everything the closure mutates
        // is either discarded on panic (the job's engine, its pruning
        // entries, its arena tiles — evicted below) or overwritten from
        // scratch on the next use (`px_buf`).
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            dispatch_job(
                worker_id,
                &registry,
                &mut engines,
                &job,
                &mut px_buf,
                &mut prune,
                &mut arena,
                &queue,
            )
        }));
        let outcome = match caught {
            Ok(Ok(outcome)) => Ok(outcome),
            Ok(Err(error)) => {
                // Recoverable failure: evict this worker's possibly
                // half-mutated state for the failed block so a retry
                // recomputes from the shipped centroids alone. The
                // arena tile lives under the job's *content* id —
                // evicting a shared tile is conservative (siblings
                // re-fill bit-identically from the same bytes).
                prune.remove(&(job.job, job.block));
                let content = engines.get(&job.job).map_or(job.job, |e| e.ctx.content);
                arena.remove((content, job.block));
                Err(JobError {
                    job: job.job,
                    block: job.block,
                    error,
                })
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                let content = engines
                    .remove(&job.job)
                    .map_or(job.job, |e| e.ctx.content);
                prune.retain(|(j, _), _| *j != job.job);
                arena.purge_job(content);
                Err(JobError {
                    job: job.job,
                    block: job.block,
                    error: anyhow!(
                        "worker {worker_id} panicked on block {}: {msg}",
                        job.block
                    ),
                })
            }
        };
        if stamped {
            watchdog.end(worker_id);
        }
        // If the leader hung up, exit quietly.
        if results.send(outcome).is_err() {
            return;
        }
    }
}

/// Resolve the job's engine (building it on first touch) and run the
/// payload.
#[allow(clippy::too_many_arguments)]
fn dispatch_job(
    worker_id: usize,
    registry: &ContextRegistry,
    engines: &mut HashMap<JobId, JobEngine>,
    job: &Job,
    px_buf: &mut Vec<f32>,
    prune: &mut HashMap<(JobId, usize), BlockPrune>,
    arena: &mut TileArena,
    queue: &JobQueue,
) -> Result<JobOutcome> {
    if !engines.contains_key(&job.job) {
        let ctx = registry.get(job.job).ok_or_else(|| {
            anyhow!("worker {worker_id}: job {} has no registered context", job.job)
        })?;
        engines.insert(job.job, JobEngine::build(worker_id, ctx)?);
    }
    // Cross-job read-ahead: under the service's round-robin interleave
    // the next queued block usually belongs to a *different* job, so the
    // current job's sidecar (consulted inside run_job) would never fire.
    // Issue the fill on the next job's own engine — but only if this
    // worker already built one; prefetch is a hint, not worth a backend
    // construction.
    if let Some((next_job, next_block)) = queue.peek_next(worker_id) {
        if next_job != job.job {
            if let Some(next_engine) = engines.get_mut(&next_job) {
                let resident = next_engine.ctx.exec.layout == TileLayout::Soa
                    && arena.contains((next_engine.ctx.content, next_block));
                if !resident {
                    if let Some(pf) = next_engine.prefetch.as_mut() {
                        pf.issue(next_block);
                    }
                }
            }
        }
    }
    let engine = engines.get_mut(&job.job).expect("just inserted");
    run_job(worker_id, engine, job, px_buf, prune, arena, queue)
}

fn run_job(
    worker_id: usize,
    engine: &mut JobEngine,
    job: &Job,
    px_buf: &mut Vec<f32>,
    prune: &mut HashMap<(JobId, usize), BlockPrune>,
    arena: &mut TileArena,
    queue: &JobQueue,
) -> Result<JobOutcome> {
    let ctx = Arc::clone(&engine.ctx);
    if let JobPayload::Ping = job.payload {
        engine
            .backend
            .warm(ctx.local_mode)
            .with_context(|| format!("worker {worker_id}: backend warmup"))?;
        return Ok(JobOutcome {
            job: job.job,
            block: job.block,
            round: job.round,
            worker: worker_id,
            timing: BlockTiming::default(),
            result: JobResult::Pong,
        });
    }
    if let Some(fault) = &ctx.fault {
        if fault.fires(job.block) {
            match fault.kind() {
                FaultKind::Error => {
                    return Err(anyhow!(
                        "injected failure on block {} (worker {worker_id})",
                        job.block
                    ));
                }
                FaultKind::Panic => {
                    panic!("injected panic on block {} (worker {worker_id})", job.block);
                }
                FaultKind::ReaderIo => {
                    return Err(anyhow::Error::new(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        format!("injected I/O error reading block {}", job.block),
                    ))
                    .context(format!("worker {worker_id}: read block {}", job.block)));
                }
                FaultKind::Hang { ms } => {
                    // Silent stall: park (bounded, release-latch aware)
                    // and then compute *normally*. No error, no panic —
                    // only the heartbeat watchdog can see this, and the
                    // late result must still be valid in case it wins.
                    fault.park(ms);
                }
            }
        }
    }

    // --- acquire block pixels ---------------------------------------------
    // Step/Assign rounds under the SoA layout hit the tile arena: the
    // block is read and deinterleaved once per job, then every later
    // round reuses the tile (or its interleaved rematerialization for
    // non-lane kernels) with zero block-source I/O. Everything else
    // takes the per-round interleaved read, exactly the seed path.
    let is_block_pass = matches!(
        job.payload,
        JobPayload::Step { .. } | JobPayload::Assign { .. }
    );
    let use_arena = is_block_pass && ctx.exec.layout == TileLayout::Soa;
    // Pruning state is private per job (bounds track each variant's own
    // centroids); decoded tiles are shared per *content* — a sweep
    // sibling's fill is this job's hit.
    let key = (job.job, job.block);
    let tile_key = (ctx.content, job.block);
    let t_io = Instant::now();
    let tile: Option<Arc<SoaTile>> = if use_arena {
        let tile = match arena.get(tile_key) {
            Some(tile) => tile,
            None => {
                // High-water budget + per-job admission cap: this job's
                // fill can never evict a bigger-budget neighbour's tiles.
                arena.raise_budget(ctx.exec.arena_bytes());
                engine
                    .read_pixels(job.block, px_buf)
                    .with_context(|| format!("worker {worker_id}: read block {}", job.block))?;
                arena.insert_within(
                    tile_key,
                    SoaTile::from_interleaved(px_buf, ctx.plan_channels()),
                    ctx.exec.arena_bytes(),
                )
            }
        };
        if !matches!(ctx.exec.kernel, KernelChoice::Lanes | KernelChoice::Simd) {
            // Interleaved compute path over an arena-resident block:
            // rematerialize (bit-identical round trip), still no I/O.
            tile.to_interleaved(px_buf);
        }
        Some(tile)
    } else {
        engine
            .read_pixels(job.block, px_buf)
            .with_context(|| format!("worker {worker_id}: read block {}", job.block))?;
        (is_block_pass && matches!(ctx.exec.kernel, KernelChoice::Lanes | KernelChoice::Simd))
            .then(|| Arc::new(SoaTile::from_interleaved(px_buf, ctx.plan_channels())))
    };
    // Double buffering: with the block in hand and compute about to
    // start, ask the sidecar to fill the next queued block of this job.
    if let Some(pf) = engine.prefetch.as_mut() {
        if let Some((next_job, next_block)) = queue.peek_next(worker_id) {
            // Same job ⇒ same content id, so this covers a sibling's
            // earlier fill of the next block too.
            let arena_resident = use_arena && arena.contains((ctx.content, next_block));
            if next_job == job.job && next_block != job.block && !arena_resident {
                pf.issue(next_block);
            }
        }
    }
    let io_secs = t_io.elapsed().as_secs_f64();
    let pixels = ctx.plan.region(job.block).area();

    let backend = engine.backend.as_mut();
    let t_c = Instant::now();
    let result = match &job.payload {
        JobPayload::Step { centroids, drift } => {
            let accum = if ctx.exec.kernel == KernelChoice::Naive {
                backend.step_block(px_buf, centroids)?
            } else {
                evict_stale(prune, job.job, job.round);
                let entry = prune.entry(key).or_default();
                let usable = entry.usable_drift(drift, job.round);
                if usable.is_none() {
                    entry.state.clear(); // stale bounds: re-seed this round
                }
                let accum = match ctx.exec.kernel {
                    KernelChoice::Lanes => backend.step_block_lanes(
                        tile.as_deref().expect("tile built for lanes"),
                        centroids,
                        &mut entry.state,
                        usable,
                    )?,
                    KernelChoice::Simd => backend.step_block_simd(
                        tile.as_deref().expect("tile built for simd"),
                        centroids,
                        &mut entry.state,
                        usable,
                        ctx.exec.simd,
                    )?,
                    _ => backend.step_block_pruned(px_buf, centroids, &mut entry.state, usable)?,
                };
                entry.last_round = Some(job.round);
                accum
            };
            JobResult::Step { accum }
        }
        JobPayload::Assign { centroids, drift } => {
            let mut labels = Vec::new();
            let inertia = match ctx.exec.kernel {
                KernelChoice::Fused | KernelChoice::Lanes | KernelChoice::Simd => {
                    evict_stale(prune, job.job, job.round);
                    let entry = prune.entry(key).or_default();
                    let usable = entry.usable_drift(drift, job.round);
                    if usable.is_none() {
                        entry.state.clear();
                    }
                    match ctx.exec.kernel {
                        KernelChoice::Lanes => backend.assign_block_lanes(
                            tile.as_deref().expect("tile built for lanes"),
                            centroids,
                            &mut entry.state,
                            usable,
                            &mut labels,
                        )?,
                        KernelChoice::Simd => backend.assign_block_simd(
                            tile.as_deref().expect("tile built for simd"),
                            centroids,
                            &mut entry.state,
                            usable,
                            &mut labels,
                            ctx.exec.simd,
                        )?,
                        _ => backend.assign_block_pruned(
                            px_buf,
                            centroids,
                            &mut entry.state,
                            usable,
                            &mut labels,
                        )?,
                    }
                }
                _ => backend.assign_block(px_buf, centroids, &mut labels)?,
            };
            JobResult::Assign { labels, inertia }
        }
        JobPayload::Local { init } => {
            let mut labels = Vec::new();
            let (centroids, inertia) = backend.local_block(px_buf, init, &mut labels)?;
            // per-cluster counts for harmonization weighting
            let k = init.len() / ctx.plan_channels();
            let mut counts = vec![0u64; k];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            JobResult::Local {
                labels,
                centroids,
                inertia,
                counts,
            }
        }
        JobPayload::Ping | JobPayload::Retire { .. } => unreachable!("handled above"),
    };
    let compute_secs = t_c.elapsed().as_secs_f64();

    Ok(JobOutcome {
        job: job.job,
        block: job.block,
        round: job.round,
        worker: worker_id,
        timing: BlockTiming {
            io_secs,
            compute_secs,
            pixels,
        },
        result,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_register_get_remove() {
        let reg = ContextRegistry::new();
        assert!(reg.is_empty());
        let img = Arc::new(crate::image::SyntheticOrtho::default().generate(8, 8));
        let ctx = Arc::new(WorkerContext {
            plan: Arc::new(BlockPlan::new(8, 8, crate::blocks::BlockShape::Square { side: 4 })),
            source: BlockSource::Direct(img),
            backend: BackendSpec::Native {
                k: 2,
                channels: 3,
                local_iters: 4,
            },
            fault: None,
            local_mode: false,
            exec: ExecPlan::default().with_arena_mb(0),
            content: crate::coordinator::messages::SOLO_JOB,
        });
        assert_eq!(reg.register(3, Arc::clone(&ctx)), 1);
        assert_eq!(reg.register(5, ctx), 2);
        assert!(reg.get(3).is_some());
        assert!(reg.get(4).is_none());
        reg.remove(3);
        assert!(reg.get(3).is_none());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn prefetcher_fills_and_discards_stale() {
        let img = Arc::new(crate::image::SyntheticOrtho::default().with_seed(9).generate(24, 20));
        let plan = Arc::new(BlockPlan::new(24, 20, crate::blocks::BlockShape::Square { side: 8 }));
        let ctx = WorkerContext {
            plan: Arc::clone(&plan),
            source: BlockSource::Direct(Arc::clone(&img)),
            backend: BackendSpec::Native {
                k: 2,
                channels: 3,
                local_iters: 1,
            },
            fault: None,
            local_mode: false,
            exec: ExecPlan::default().with_arena_mb(0).with_prefetch(true),
            content: crate::coordinator::messages::SOLO_JOB,
        };
        let mut pf = Prefetcher::spawn(0, &ctx).unwrap();
        // predicted correctly: the buffer is exactly the block crop
        pf.issue(1);
        let got = pf.take(1).expect("in-flight fill").unwrap();
        assert_eq!(got, img.crop(plan.region(1)));
        // mispredicted: asking for block 0 banks block 2's buffer …
        pf.issue(2);
        assert!(pf.take(0).is_none(), "mispredict must fall back to sync");
        // … which is still served when block 2 does come up
        let got2 = pf.take(2).expect("banked fill").unwrap();
        assert_eq!(got2, img.crop(plan.region(2)));
        assert!(pf.take(2).is_none(), "buffer is consumed once");
    }

    #[test]
    fn eviction_is_scoped_to_one_job() {
        let mut prune: HashMap<(JobId, usize), BlockPrune> = HashMap::new();
        prune.insert(
            (1, 0),
            BlockPrune {
                state: PrunedState::new(),
                last_round: Some(0),
            },
        );
        prune.insert(
            (2, 0),
            BlockPrune {
                state: PrunedState::new(),
                last_round: Some(0),
            },
        );
        // job 1 jumps to round 5: its stale entry goes, job 2's survives
        evict_stale(&mut prune, 1, 5);
        assert!(!prune.contains_key(&(1, 0)));
        assert!(prune.contains_key(&(2, 0)));
    }
}
