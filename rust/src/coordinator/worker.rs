//! Worker thread: pulls tagged jobs, reads the block, runs the backend.
//!
//! Since the service layer landed, one worker serves **many concurrent
//! clustering jobs**: per-job contexts are looked up in a shared
//! [`ContextRegistry`], and all mutable worker state — compute backend,
//! block reader, pruned bounds — is keyed by [`JobId`] (bounds by
//! `(job, block)`) so interleaved jobs can never contaminate each other.
//! A [`JobPayload::Retire`] message drops a finished job's cached state.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::messages::{BlockTiming, Job, JobError, JobId, JobOutcome, JobPayload, JobResult};
use super::queue::JobQueue;
use crate::blocks::BlockPlan;
use crate::image::Raster;
use crate::kmeans::kernel::{CentroidDrift, KernelChoice, PrunedState};
use crate::runtime::BackendSpec;
use crate::stripstore::{StripReader, StripStore};

/// Where workers get block pixels from.
#[derive(Clone)]
pub enum BlockSource {
    /// Crop directly from the shared in-memory raster.
    Direct(Arc<Raster>),
    /// Read via a strip store (whole-strip reads, counted) — the
    /// `blockproc` I/O model.
    Strips(Arc<StripStore>),
}

/// Everything a worker needs to process one job's blocks. One instance
/// per clustering job, shared by all workers through the pool's
/// [`ContextRegistry`].
#[derive(Clone)]
pub struct WorkerContext {
    pub plan: Arc<BlockPlan>,
    pub source: BlockSource,
    pub backend: BackendSpec,
    /// Fault injection: processing this block index fails (tests).
    pub fail_block: Option<usize>,
    /// Hint for backend warmup: will this job use per-block local mode?
    pub local_mode: bool,
    /// Which compute kernel step/assign jobs run (see
    /// [`crate::kmeans::kernel`]). Pruned/fused kernels keep per-block
    /// Hamerly bounds across rounds; results are bit-identical to naive.
    pub kernel: KernelChoice,
}

impl WorkerContext {
    /// Channel count of the underlying imagery.
    pub fn plan_channels(&self) -> usize {
        match &self.source {
            BlockSource::Direct(r) => r.channels(),
            BlockSource::Strips(s) => s.channels(),
        }
    }
}

/// Shared map of job id → per-job worker context. The leader registers a
/// context before submitting any of the job's blocks and removes it when
/// the job retires; workers resolve contexts lazily on first touch.
#[derive(Default)]
pub struct ContextRegistry {
    inner: RwLock<HashMap<JobId, Arc<WorkerContext>>>,
}

impl ContextRegistry {
    pub fn new() -> ContextRegistry {
        ContextRegistry::default()
    }

    /// Register (or replace) the context for `job`. Returns the number
    /// of jobs now registered.
    pub fn register(&self, job: JobId, ctx: Arc<WorkerContext>) -> usize {
        let mut map = self.inner.write().unwrap();
        map.insert(job, ctx);
        map.len()
    }

    pub fn remove(&self, job: JobId) {
        self.inner.write().unwrap().remove(&job);
    }

    pub fn get(&self, job: JobId) -> Option<Arc<WorkerContext>> {
        self.inner.read().unwrap().get(&job).cloned()
    }

    /// Number of currently registered jobs.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-(job, block) pruning state a worker carries across rounds.
/// `last_round` records the round whose centroids the bounds describe; a
/// job whose drift does not continue exactly from that round re-seeds
/// the bounds with a full scan (dynamic scheduling can migrate blocks
/// between workers, which must never change results).
#[derive(Default)]
struct BlockPrune {
    state: PrunedState,
    last_round: Option<u64>,
}

impl BlockPrune {
    /// The shipped drift is usable iff it advances this state by exactly
    /// one round.
    fn usable_drift<'d>(
        &self,
        drift: &'d Option<Arc<CentroidDrift>>,
        round: u64,
    ) -> Option<&'d CentroidDrift> {
        match (drift, self.last_round) {
            (Some(d), Some(r)) if r.checked_add(1) == Some(round) => Some(d.as_ref()),
            _ => None,
        }
    }
}

/// Drop pruning state of `job` that cannot continue into `round` (its
/// block migrated to another worker or skipped a round — it would
/// re-seed anyway). Other jobs' entries are untouched: eviction is keyed
/// by (job, block), so an interleaved neighbour's warm bounds survive.
/// Under a static schedule every owned block sits at `round` or
/// `round - 1` and is kept; under a dynamic schedule a migrated-away
/// block's orphaned state (20 bytes/pixel) is reclaimed within a round
/// instead of accumulating for the life of the pool.
fn evict_stale(prune: &mut HashMap<(JobId, usize), BlockPrune>, job: JobId, round: u64) {
    prune.retain(|(j, _), e| {
        *j != job || e.last_round.is_some_and(|r| r.saturating_add(1) >= round)
    });
}

/// Per-worker block reader (owns file handles / scratch).
enum Reader {
    Direct(Arc<Raster>),
    Strips(Box<StripReader>),
}

impl Reader {
    fn read(&mut self, plan: &BlockPlan, block: usize, buf: &mut Vec<f32>) -> Result<()> {
        let region = plan.region(block);
        match self {
            Reader::Direct(raster) => {
                raster.crop_into(region, buf);
                Ok(())
            }
            Reader::Strips(reader) => reader.read_block(region, buf),
        }
    }
}

/// One job's lazily-built worker-local machinery: the compute backend
/// (PJRT client or native math) plus the block reader (own file handle).
struct JobEngine {
    ctx: Arc<WorkerContext>,
    backend: Box<dyn crate::runtime::ComputeBackend>,
    reader: Reader,
}

impl JobEngine {
    fn build(worker_id: usize, ctx: Arc<WorkerContext>) -> Result<JobEngine> {
        let backend = ctx
            .backend
            .build()
            .with_context(|| format!("worker {worker_id}: backend init"))?;
        let reader = match &ctx.source {
            BlockSource::Direct(r) => Reader::Direct(Arc::clone(r)),
            BlockSource::Strips(s) => Reader::Strips(Box::new(
                s.reader()
                    .with_context(|| format!("worker {worker_id}: open reader"))?,
            )),
        };
        Ok(JobEngine {
            ctx,
            backend,
            reader,
        })
    }
}

/// Worker main loop. Runs on its own thread until the queue closes.
/// Every job message except [`JobPayload::Retire`] produces exactly one
/// message on `results` (Ok or Err), so the leader can count responses
/// without tracking worker liveness.
pub fn worker_main(
    worker_id: usize,
    registry: Arc<ContextRegistry>,
    queue: Arc<JobQueue>,
    results: Sender<Result<JobOutcome, JobError>>,
) {
    let mut engines: HashMap<JobId, JobEngine> = HashMap::new();
    let mut px_buf: Vec<f32> = Vec::new();
    let mut prune: HashMap<(JobId, usize), BlockPrune> = HashMap::new();
    while let Some(job) = queue.pop(worker_id) {
        if matches!(job.payload, JobPayload::Retire) {
            engines.remove(&job.job);
            prune.retain(|(j, _), _| *j != job.job);
            continue;
        }
        let outcome = dispatch_job(worker_id, &registry, &mut engines, &job, &mut px_buf, &mut prune);
        let outcome = outcome.map_err(|error| JobError {
            job: job.job,
            block: job.block,
            error,
        });
        // If the leader hung up, exit quietly.
        if results.send(outcome).is_err() {
            return;
        }
    }
}

/// Resolve the job's engine (building it on first touch) and run the
/// payload.
fn dispatch_job(
    worker_id: usize,
    registry: &ContextRegistry,
    engines: &mut HashMap<JobId, JobEngine>,
    job: &Job,
    px_buf: &mut Vec<f32>,
    prune: &mut HashMap<(JobId, usize), BlockPrune>,
) -> Result<JobOutcome> {
    if !engines.contains_key(&job.job) {
        let ctx = registry.get(job.job).ok_or_else(|| {
            anyhow!("worker {worker_id}: job {} has no registered context", job.job)
        })?;
        engines.insert(job.job, JobEngine::build(worker_id, ctx)?);
    }
    let engine = engines.get_mut(&job.job).expect("just inserted");
    run_job(worker_id, engine, job, px_buf, prune)
}

fn run_job(
    worker_id: usize,
    engine: &mut JobEngine,
    job: &Job,
    px_buf: &mut Vec<f32>,
    prune: &mut HashMap<(JobId, usize), BlockPrune>,
) -> Result<JobOutcome> {
    let ctx = &engine.ctx;
    if let JobPayload::Ping = job.payload {
        engine
            .backend
            .warm(ctx.local_mode)
            .with_context(|| format!("worker {worker_id}: backend warmup"))?;
        return Ok(JobOutcome {
            job: job.job,
            block: job.block,
            round: job.round,
            worker: worker_id,
            timing: BlockTiming::default(),
            result: JobResult::Pong,
        });
    }
    if ctx.fail_block == Some(job.block) {
        return Err(anyhow!(
            "injected failure on block {} (worker {worker_id})",
            job.block
        ));
    }
    let t_io = Instant::now();
    engine
        .reader
        .read(&ctx.plan, job.block, px_buf)
        .with_context(|| format!("worker {worker_id}: read block {}", job.block))?;
    let io_secs = t_io.elapsed().as_secs_f64();
    let pixels = ctx.plan.region(job.block).area();

    let backend = engine.backend.as_mut();
    let key = (job.job, job.block);
    let t_c = Instant::now();
    let result = match &job.payload {
        JobPayload::Step { centroids, drift } => {
            let accum = if ctx.kernel == KernelChoice::Naive {
                backend.step_block(px_buf, centroids)?
            } else {
                evict_stale(prune, job.job, job.round);
                let entry = prune.entry(key).or_default();
                let usable = entry.usable_drift(drift, job.round);
                if usable.is_none() {
                    entry.state.clear(); // stale bounds: re-seed this round
                }
                let accum =
                    backend.step_block_pruned(px_buf, centroids, &mut entry.state, usable)?;
                entry.last_round = Some(job.round);
                accum
            };
            JobResult::Step { accum }
        }
        JobPayload::Assign { centroids, drift } => {
            let mut labels = Vec::new();
            let inertia = if ctx.kernel == KernelChoice::Fused {
                evict_stale(prune, job.job, job.round);
                let entry = prune.entry(key).or_default();
                let usable = entry.usable_drift(drift, job.round);
                if usable.is_none() {
                    entry.state.clear();
                }
                backend.assign_block_pruned(px_buf, centroids, &mut entry.state, usable, &mut labels)?
            } else {
                backend.assign_block(px_buf, centroids, &mut labels)?
            };
            JobResult::Assign { labels, inertia }
        }
        JobPayload::Local { init } => {
            let mut labels = Vec::new();
            let (centroids, inertia) = backend.local_block(px_buf, init, &mut labels)?;
            // per-cluster counts for harmonization weighting
            let k = init.len() / ctx.plan_channels();
            let mut counts = vec![0u64; k];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            JobResult::Local {
                labels,
                centroids,
                inertia,
                counts,
            }
        }
        JobPayload::Ping | JobPayload::Retire => unreachable!("handled above"),
    };
    let compute_secs = t_c.elapsed().as_secs_f64();

    Ok(JobOutcome {
        job: job.job,
        block: job.block,
        round: job.round,
        worker: worker_id,
        timing: BlockTiming {
            io_secs,
            compute_secs,
            pixels,
        },
        result,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_register_get_remove() {
        let reg = ContextRegistry::new();
        assert!(reg.is_empty());
        let img = Arc::new(crate::image::SyntheticOrtho::default().generate(8, 8));
        let ctx = Arc::new(WorkerContext {
            plan: Arc::new(BlockPlan::new(8, 8, crate::blocks::BlockShape::Square { side: 4 })),
            source: BlockSource::Direct(img),
            backend: BackendSpec::Native {
                k: 2,
                channels: 3,
                local_iters: 4,
            },
            fail_block: None,
            local_mode: false,
            kernel: KernelChoice::Naive,
        });
        assert_eq!(reg.register(3, Arc::clone(&ctx)), 1);
        assert_eq!(reg.register(5, ctx), 2);
        assert!(reg.get(3).is_some());
        assert!(reg.get(4).is_none());
        reg.remove(3);
        assert!(reg.get(3).is_none());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn eviction_is_scoped_to_one_job() {
        let mut prune: HashMap<(JobId, usize), BlockPrune> = HashMap::new();
        prune.insert(
            (1, 0),
            BlockPrune {
                state: PrunedState::new(),
                last_round: Some(0),
            },
        );
        prune.insert(
            (2, 0),
            BlockPrune {
                state: PrunedState::new(),
                last_round: Some(0),
            },
        );
        // job 1 jumps to round 5: its stale entry goes, job 2's survives
        evict_stale(&mut prune, 1, 5);
        assert!(!prune.contains_key(&(1, 0)));
        assert!(prune.contains_key(&(2, 0)));
    }
}
