//! Worker thread: pulls jobs, reads its block, runs the backend.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::messages::{BlockTiming, Job, JobOutcome, JobPayload, JobResult};
use super::queue::JobQueue;
use crate::blocks::BlockPlan;
use crate::image::Raster;
use crate::kmeans::kernel::{CentroidDrift, KernelChoice, PrunedState};
use crate::runtime::BackendSpec;
use crate::stripstore::{StripReader, StripStore};

/// Where workers get block pixels from.
#[derive(Clone)]
pub enum BlockSource {
    /// Crop directly from the shared in-memory raster.
    Direct(Arc<Raster>),
    /// Read via a strip store (whole-strip reads, counted) — the
    /// `blockproc` I/O model.
    Strips(Arc<StripStore>),
}

/// Everything a worker thread needs, cheap to clone per worker.
#[derive(Clone)]
pub struct WorkerContext {
    pub plan: Arc<BlockPlan>,
    pub source: BlockSource,
    pub backend: BackendSpec,
    /// Fault injection: processing this block index fails (tests).
    pub fail_block: Option<usize>,
    /// Hint for backend warmup: will this run use per-block local mode?
    pub local_mode: bool,
    /// Which compute kernel step/assign jobs run (see
    /// [`crate::kmeans::kernel`]). Pruned/fused kernels keep per-block
    /// Hamerly bounds across rounds; results are bit-identical to naive.
    pub kernel: KernelChoice,
}

/// Per-block pruning state a worker carries across rounds. `last_round`
/// records the round whose centroids the bounds describe; a job whose
/// drift does not continue exactly from that round re-seeds the bounds
/// with a full scan (dynamic scheduling can migrate blocks between
/// workers, which must never change results).
#[derive(Default)]
struct BlockPrune {
    state: PrunedState,
    last_round: Option<u64>,
}

/// Drop pruning state that cannot continue into `round` (its block
/// migrated to another worker or skipped a round — it would re-seed
/// anyway). Bounds the map at roughly this worker's share of the plan:
/// under a static schedule every owned block sits at `round` or
/// `round - 1` and is kept; under a dynamic schedule a migrated-away
/// block's orphaned state (20 bytes/pixel) is reclaimed within a round
/// instead of accumulating for the life of the pool.
fn evict_stale(prune: &mut HashMap<usize, BlockPrune>, round: u64) {
    prune.retain(|_, e| e.last_round.is_some_and(|r| r.saturating_add(1) >= round));
}

impl BlockPrune {
    /// The shipped drift is usable iff it advances this state by exactly
    /// one round.
    fn usable_drift<'d>(
        &self,
        drift: &'d Option<Arc<CentroidDrift>>,
        round: u64,
    ) -> Option<&'d CentroidDrift> {
        match (drift, self.last_round) {
            (Some(d), Some(r)) if r.checked_add(1) == Some(round) => Some(d.as_ref()),
            _ => None,
        }
    }
}

/// Per-worker block reader (owns file handles / scratch).
enum Reader {
    Direct(Arc<Raster>),
    Strips(Box<StripReader>),
}

impl Reader {
    fn read(&mut self, ctx: &WorkerContext, block: usize, buf: &mut Vec<f32>) -> Result<()> {
        let region = ctx.plan.region(block);
        match self {
            Reader::Direct(raster) => {
                raster.crop_into(region, buf);
                Ok(())
            }
            Reader::Strips(reader) => reader.read_block(region, buf),
        }
    }
}

/// Worker main loop. Runs on its own thread until the queue closes.
/// Every job produces exactly one message on `results` (Ok or Err), so
/// the leader can count responses without tracking worker liveness.
pub fn worker_main(
    worker_id: usize,
    ctx: WorkerContext,
    queue: Arc<JobQueue>,
    results: Sender<Result<JobOutcome>>,
) {
    // Build this worker's private engine (PJRT client or native math).
    let mut backend = match ctx.backend.build() {
        Ok(b) => b,
        Err(e) => {
            let _ = results.send(Err(e.context(format!("worker {worker_id}: backend init"))));
            return;
        }
    };
    let mut reader = match &ctx.source {
        BlockSource::Direct(r) => Reader::Direct(Arc::clone(r)),
        BlockSource::Strips(s) => match s.reader() {
            Ok(rd) => Reader::Strips(Box::new(rd)),
            Err(e) => {
                let _ = results.send(Err(e.context(format!("worker {worker_id}: open reader"))));
                return;
            }
        },
    };

    let mut px_buf: Vec<f32> = Vec::new();
    let mut prune: HashMap<usize, BlockPrune> = HashMap::new();
    while let Some(job) = queue.pop(worker_id) {
        let outcome = run_job(
            worker_id,
            &ctx,
            &mut reader,
            backend.as_mut(),
            &job,
            &mut px_buf,
            &mut prune,
        );
        // If the leader hung up, exit quietly.
        if results.send(outcome).is_err() {
            return;
        }
    }
}

fn run_job(
    worker_id: usize,
    ctx: &WorkerContext,
    reader: &mut Reader,
    backend: &mut dyn crate::runtime::ComputeBackend,
    job: &Job,
    px_buf: &mut Vec<f32>,
    prune: &mut HashMap<usize, BlockPrune>,
) -> Result<JobOutcome> {
    if let JobPayload::Ping = job.payload {
        backend
            .warm(ctx.local_mode)
            .with_context(|| format!("worker {worker_id}: backend warmup"))?;
        return Ok(JobOutcome {
            block: job.block,
            round: job.round,
            worker: worker_id,
            timing: BlockTiming::default(),
            result: JobResult::Pong,
        });
    }
    if ctx.fail_block == Some(job.block) {
        return Err(anyhow!(
            "injected failure on block {} (worker {worker_id})",
            job.block
        ));
    }
    let t_io = Instant::now();
    reader
        .read(ctx, job.block, px_buf)
        .with_context(|| format!("worker {worker_id}: read block {}", job.block))?;
    let io_secs = t_io.elapsed().as_secs_f64();
    let pixels = ctx.plan.region(job.block).area();

    let t_c = Instant::now();
    let result = match &job.payload {
        JobPayload::Step { centroids, drift } => {
            let accum = if ctx.kernel == KernelChoice::Naive {
                backend.step_block(px_buf, centroids)?
            } else {
                evict_stale(prune, job.round);
                let entry = prune.entry(job.block).or_default();
                let usable = entry.usable_drift(drift, job.round);
                if usable.is_none() {
                    entry.state.clear(); // stale bounds: re-seed this round
                }
                let accum =
                    backend.step_block_pruned(px_buf, centroids, &mut entry.state, usable)?;
                entry.last_round = Some(job.round);
                accum
            };
            JobResult::Step { accum }
        }
        JobPayload::Assign { centroids, drift } => {
            let mut labels = Vec::new();
            let inertia = if ctx.kernel == KernelChoice::Fused {
                evict_stale(prune, job.round);
                let entry = prune.entry(job.block).or_default();
                let usable = entry.usable_drift(drift, job.round);
                if usable.is_none() {
                    entry.state.clear();
                }
                backend.assign_block_pruned(px_buf, centroids, &mut entry.state, usable, &mut labels)?
            } else {
                backend.assign_block(px_buf, centroids, &mut labels)?
            };
            JobResult::Assign { labels, inertia }
        }
        JobPayload::Ping => unreachable!("handled above"),
        JobPayload::Local { init } => {
            let mut labels = Vec::new();
            let (centroids, inertia) = backend.local_block(px_buf, init, &mut labels)?;
            // per-cluster counts for harmonization weighting
            let k = init.len() / ctx.plan_channels();
            let mut counts = vec![0u64; k];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            JobResult::Local {
                labels,
                centroids,
                inertia,
                counts,
            }
        }
    };
    let compute_secs = t_c.elapsed().as_secs_f64();

    Ok(JobOutcome {
        block: job.block,
        round: job.round,
        worker: worker_id,
        timing: BlockTiming {
            io_secs,
            compute_secs,
            pixels,
        },
        result,
    })
}

impl WorkerContext {
    /// Channel count of the underlying imagery.
    pub fn plan_channels(&self) -> usize {
        match &self.source {
            BlockSource::Direct(r) => r.channels(),
            BlockSource::Strips(s) => s.channels(),
        }
    }
}
