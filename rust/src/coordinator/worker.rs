//! Worker thread: pulls jobs, reads its block, runs the backend.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::messages::{BlockTiming, Job, JobOutcome, JobPayload, JobResult};
use super::queue::JobQueue;
use crate::blocks::BlockPlan;
use crate::image::Raster;
use crate::runtime::BackendSpec;
use crate::stripstore::{StripReader, StripStore};

/// Where workers get block pixels from.
#[derive(Clone)]
pub enum BlockSource {
    /// Crop directly from the shared in-memory raster.
    Direct(Arc<Raster>),
    /// Read via a strip store (whole-strip reads, counted) — the
    /// `blockproc` I/O model.
    Strips(Arc<StripStore>),
}

/// Everything a worker thread needs, cheap to clone per worker.
#[derive(Clone)]
pub struct WorkerContext {
    pub plan: Arc<BlockPlan>,
    pub source: BlockSource,
    pub backend: BackendSpec,
    /// Fault injection: processing this block index fails (tests).
    pub fail_block: Option<usize>,
    /// Hint for backend warmup: will this run use per-block local mode?
    pub local_mode: bool,
}

/// Per-worker block reader (owns file handles / scratch).
enum Reader {
    Direct(Arc<Raster>),
    Strips(Box<StripReader>),
}

impl Reader {
    fn read(&mut self, ctx: &WorkerContext, block: usize, buf: &mut Vec<f32>) -> Result<()> {
        let region = ctx.plan.region(block);
        match self {
            Reader::Direct(raster) => {
                raster.crop_into(region, buf);
                Ok(())
            }
            Reader::Strips(reader) => reader.read_block(region, buf),
        }
    }
}

/// Worker main loop. Runs on its own thread until the queue closes.
/// Every job produces exactly one message on `results` (Ok or Err), so
/// the leader can count responses without tracking worker liveness.
pub fn worker_main(
    worker_id: usize,
    ctx: WorkerContext,
    queue: Arc<JobQueue>,
    results: Sender<Result<JobOutcome>>,
) {
    // Build this worker's private engine (PJRT client or native math).
    let mut backend = match ctx.backend.build() {
        Ok(b) => b,
        Err(e) => {
            let _ = results.send(Err(e.context(format!("worker {worker_id}: backend init"))));
            return;
        }
    };
    let mut reader = match &ctx.source {
        BlockSource::Direct(r) => Reader::Direct(Arc::clone(r)),
        BlockSource::Strips(s) => match s.reader() {
            Ok(rd) => Reader::Strips(Box::new(rd)),
            Err(e) => {
                let _ = results.send(Err(e.context(format!("worker {worker_id}: open reader"))));
                return;
            }
        },
    };

    let mut px_buf: Vec<f32> = Vec::new();
    while let Some(job) = queue.pop(worker_id) {
        let outcome = run_job(worker_id, &ctx, &mut reader, backend.as_mut(), &job, &mut px_buf);
        // If the leader hung up, exit quietly.
        if results.send(outcome).is_err() {
            return;
        }
    }
}

fn run_job(
    worker_id: usize,
    ctx: &WorkerContext,
    reader: &mut Reader,
    backend: &mut dyn crate::runtime::ComputeBackend,
    job: &Job,
    px_buf: &mut Vec<f32>,
) -> Result<JobOutcome> {
    if let JobPayload::Ping = job.payload {
        backend
            .warm(ctx.local_mode)
            .with_context(|| format!("worker {worker_id}: backend warmup"))?;
        return Ok(JobOutcome {
            block: job.block,
            round: job.round,
            worker: worker_id,
            timing: BlockTiming::default(),
            result: JobResult::Pong,
        });
    }
    if ctx.fail_block == Some(job.block) {
        return Err(anyhow!(
            "injected failure on block {} (worker {worker_id})",
            job.block
        ));
    }
    let t_io = Instant::now();
    reader
        .read(ctx, job.block, px_buf)
        .with_context(|| format!("worker {worker_id}: read block {}", job.block))?;
    let io_secs = t_io.elapsed().as_secs_f64();
    let pixels = ctx.plan.region(job.block).area();

    let t_c = Instant::now();
    let result = match &job.payload {
        JobPayload::Step { centroids } => JobResult::Step {
            accum: backend.step_block(px_buf, centroids)?,
        },
        JobPayload::Assign { centroids } => {
            let mut labels = Vec::new();
            let inertia = backend.assign_block(px_buf, centroids, &mut labels)?;
            JobResult::Assign { labels, inertia }
        }
        JobPayload::Ping => unreachable!("handled above"),
        JobPayload::Local { init } => {
            let mut labels = Vec::new();
            let (centroids, inertia) = backend.local_block(px_buf, init, &mut labels)?;
            // per-cluster counts for harmonization weighting
            let k = init.len() / ctx.plan_channels();
            let mut counts = vec![0u64; k];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            JobResult::Local {
                labels,
                centroids,
                inertia,
                counts,
            }
        }
    };
    let compute_secs = t_c.elapsed().as_secs_f64();

    Ok(JobOutcome {
        block: job.block,
        round: job.round,
        worker: worker_id,
        timing: BlockTiming {
            io_secs,
            compute_secs,
            pixels,
        },
        result,
    })
}

impl WorkerContext {
    /// Channel count of the underlying imagery.
    pub fn plan_channels(&self) -> usize {
        match &self.source {
            BlockSource::Direct(r) => r.channels(),
            BlockSource::Strips(s) => s.channels(),
        }
    }
}
