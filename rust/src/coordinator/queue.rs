//! Job distribution: static (pre-assigned) or dynamic (shared) queues.
//!
//! MATLAB's `parfor`/`blockproc` schedules blocks onto parpool workers
//! dynamically; a static round-robin split is the classic alternative the
//! ablation bench compares (static splits suffer when block costs are
//! skewed, e.g. partial edge blocks). Both are one structure: a set of
//! per-worker deques plus an optional shared overflow — `pop(worker)`
//! drains the worker's own deque first, then (dynamic mode) steals from
//! the shared pool.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::messages::Job;

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Blocks pre-assigned round-robin; no stealing.
    Static,
    /// Single shared queue; workers pull as they finish (default; what
    /// `parfor` does).
    Dynamic,
}

impl std::str::FromStr for Schedule {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Ok(Schedule::Static),
            "dynamic" => Ok(Schedule::Dynamic),
            other => Err(format!("unknown schedule {other:?} (want static|dynamic)")),
        }
    }
}

struct QueueState {
    /// Per-worker private queues (static mode).
    per_worker: Vec<VecDeque<Job>>,
    /// Shared queue (dynamic mode).
    shared: VecDeque<Job>,
    /// No more jobs will ever arrive.
    closed: bool,
}

/// Blocking multi-worker job queue.
pub struct JobQueue {
    schedule: Schedule,
    state: Mutex<QueueState>,
    cond: Condvar,
}

impl JobQueue {
    pub fn new(workers: usize, schedule: Schedule) -> JobQueue {
        assert!(workers > 0);
        JobQueue {
            schedule,
            state: Mutex::new(QueueState {
                per_worker: (0..workers).map(|_| VecDeque::new()).collect(),
                shared: VecDeque::new(),
                closed: false,
            }),
            cond: Condvar::new(),
        }
    }

    pub fn schedule(&self) -> Schedule {
        self.schedule
    }

    /// Enqueue a round of jobs. Static: round-robin over workers (block
    /// `i` → worker `i % W`, matching the deterministic split MATLAB's
    /// `spmd` codistributor would make). Dynamic: one shared queue.
    pub fn push_round(&self, jobs: Vec<Job>) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "push after close");
        match self.schedule {
            Schedule::Static => {
                let w = st.per_worker.len();
                for (i, job) in jobs.into_iter().enumerate() {
                    st.per_worker[i % w].push_back(job);
                }
            }
            Schedule::Dynamic => st.shared.extend(jobs),
        }
        drop(st);
        self.cond.notify_all();
    }

    /// Blocking pop for `worker`. Returns `None` once the queue is closed
    /// and empty (for this worker).
    pub fn pop(&self, worker: usize) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.per_worker[worker].pop_front() {
                return Some(job);
            }
            if let Some(job) = st.shared.pop_front() {
                return Some(job);
            }
            if st.closed {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Enqueue a job for one specific worker (barrier pings), regardless
    /// of schedule mode.
    pub fn push_to_worker(&self, worker: usize, job: Job) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "push after close");
        st.per_worker[worker].push_back(job);
        drop(st);
        self.cond.notify_all();
    }

    /// Close the queue; workers drain what remains and exit.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Jobs currently waiting (for tests / introspection).
    pub fn pending(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.shared.len() + st.per_worker.iter().map(VecDeque::len).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::JobPayload;
    use std::sync::Arc;

    fn job(block: usize) -> Job {
        Job {
            block,
            round: 0,
            payload: JobPayload::Step {
                centroids: Arc::new(vec![0.0; 6]),
                drift: None,
            },
        }
    }

    #[test]
    fn static_round_robin_assignment() {
        let q = JobQueue::new(2, Schedule::Static);
        q.push_round((0..5).map(job).collect());
        // worker 0 gets blocks 0,2,4; worker 1 gets 1,3
        assert_eq!(q.pop(0).unwrap().block, 0);
        assert_eq!(q.pop(0).unwrap().block, 2);
        assert_eq!(q.pop(1).unwrap().block, 1);
        assert_eq!(q.pop(0).unwrap().block, 4);
        assert_eq!(q.pop(1).unwrap().block, 3);
        q.close();
        assert!(q.pop(0).is_none());
        assert!(q.pop(1).is_none());
    }

    #[test]
    fn dynamic_any_worker_drains() {
        let q = JobQueue::new(3, Schedule::Dynamic);
        q.push_round((0..4).map(job).collect());
        let mut got: Vec<usize> = (0..4).map(|i| q.pop(i % 3).unwrap().block).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = Arc::new(JobQueue::new(1, Schedule::Dynamic));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop(0).map(|j| j.block));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push_round(vec![job(7)]);
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q = Arc::new(JobQueue::new(2, Schedule::Dynamic));
        let handles: Vec<_> = (0..2)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.pop(w).is_none())
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        for h in handles {
            assert!(h.join().unwrap(), "worker should see closed queue");
        }
    }

    #[test]
    #[should_panic(expected = "push after close")]
    fn push_after_close_panics() {
        let q = JobQueue::new(1, Schedule::Dynamic);
        q.close();
        q.push_round(vec![job(0)]);
    }

    #[test]
    fn schedule_parses() {
        assert_eq!("static".parse::<Schedule>().unwrap(), Schedule::Static);
        assert_eq!("Dynamic".parse::<Schedule>().unwrap(), Schedule::Dynamic);
        assert!("rr".parse::<Schedule>().is_err());
    }
}
