//! Job distribution: static (pre-assigned) or dynamic (shared) queues,
//! with fair cross-job interleaving.
//!
//! MATLAB's `parfor`/`blockproc` schedules blocks onto parpool workers
//! dynamically; a static round-robin split is the classic alternative the
//! ablation bench compares (static splits suffer when block costs are
//! skewed, e.g. partial edge blocks). Both are one structure: a set of
//! per-worker deques plus shared per-job deques — `pop(worker)` drains
//! the worker's own deque first, then (dynamic mode) takes from the
//! shared pool.
//!
//! The shared pool is segregated **per job** and drained round-robin
//! across job ids: when blocks from several images/jobs are in flight at
//! once (the service's multi-job mode), workers alternate between jobs
//! instead of head-of-line-blocking on whichever job enqueued first.
//! Within one job, blocks keep their enqueue order.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};

use super::messages::{Job, JobId, JobPayload};

/// Scheduling policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Schedule {
    /// Blocks pre-assigned round-robin; no stealing.
    Static,
    /// Shared per-job queues; workers pull as they finish (default; what
    /// `parfor` does), interleaving fairly across jobs.
    #[default]
    Dynamic,
}

impl std::str::FromStr for Schedule {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Ok(Schedule::Static),
            "dynamic" => Ok(Schedule::Dynamic),
            other => Err(format!("unknown schedule {other:?} (want static|dynamic)")),
        }
    }
}

struct QueueState {
    /// Per-worker private queues (static mode, pings, retirements).
    per_worker: Vec<VecDeque<Job>>,
    /// Shared work, one non-empty deque per job id (dynamic mode).
    shared: BTreeMap<JobId, VecDeque<Job>>,
    /// Round-robin rotation over the job ids present in `shared`.
    rotation: VecDeque<JobId>,
    /// Share-group tag per job id (sweep variants over one image). Ids
    /// in the same group are kept **adjacent** in the rotation so
    /// workers visit a block for every sibling back-to-back while its
    /// decoded tile is still hot in the arena. Ungrouped ids keep the
    /// plain round-robin order.
    groups: BTreeMap<JobId, u64>,
    /// QoS priority per job id (0 = default). The rotation drains the
    /// highest-priority queued job first; equal priorities keep the
    /// fair round-robin interleave (an untagged service is exactly the
    /// pre-QoS queue).
    priorities: BTreeMap<JobId, usize>,
    /// High water of distinct jobs simultaneously queued in `shared`
    /// (instrumentation for the admission-cap tests).
    max_jobs_interleaved: usize,
    /// No more jobs will ever arrive.
    closed: bool,
}

impl QueueState {
    /// Append to a job's shared deque, keeping `rotation` in sync (an id
    /// is in the rotation iff its deque is non-empty).
    fn push_shared(&mut self, job: Job) {
        let q = self.shared.entry(job.job).or_default();
        if q.is_empty() {
            let id = job.job;
            // Group affinity: slot the id directly after the last
            // rotation entry of its share group, so same-image
            // variants are popped consecutively. No group (or no
            // sibling queued) → plain fair push_back.
            let slot = self.groups.get(&id).and_then(|g| {
                self.rotation
                    .iter()
                    .rposition(|other| self.groups.get(other) == Some(g))
            });
            match slot {
                Some(pos) => self.rotation.insert(pos + 1, id),
                None => self.rotation.push_back(id),
            }
        }
        q.push_back(job);
        self.max_jobs_interleaved = self.max_jobs_interleaved.max(self.shared.len());
    }

    /// The rotation position to drain next: the first id carrying the
    /// maximum priority. With no priorities tagged this is always the
    /// front — the plain fair rotation.
    fn next_rotation_idx(&self) -> Option<usize> {
        if self.rotation.is_empty() {
            return None;
        }
        if self.priorities.is_empty() {
            return Some(0);
        }
        let mut best = 0usize;
        let mut best_p = self.priorities.get(&self.rotation[0]).copied().unwrap_or(0);
        for (i, id) in self.rotation.iter().enumerate().skip(1) {
            let p = self.priorities.get(id).copied().unwrap_or(0);
            if p > best_p {
                best = i;
                best_p = p;
            }
        }
        Some(best)
    }

    /// Take the next shared job: highest priority first, fair rotation
    /// among equals.
    fn pop_shared(&mut self) -> Option<Job> {
        let idx = self.next_rotation_idx()?;
        let id = self.rotation.remove(idx).expect("index from next_rotation_idx");
        let q = self.shared.get_mut(&id).expect("rotation/shared in sync");
        let job = q.pop_front().expect("rotation ids have non-empty deques");
        if q.is_empty() {
            self.shared.remove(&id);
        } else {
            self.rotation.push_back(id);
        }
        Some(job)
    }
}

/// Blocking multi-worker, multi-job job queue.
pub struct JobQueue {
    schedule: Schedule,
    state: Mutex<QueueState>,
    cond: Condvar,
}

impl JobQueue {
    pub fn new(workers: usize, schedule: Schedule) -> JobQueue {
        assert!(workers > 0);
        JobQueue {
            schedule,
            state: Mutex::new(QueueState {
                per_worker: (0..workers).map(|_| VecDeque::new()).collect(),
                shared: BTreeMap::new(),
                rotation: VecDeque::new(),
                groups: BTreeMap::new(),
                max_jobs_interleaved: 0,
                closed: false,
            }),
            cond: Condvar::new(),
        }
    }

    pub fn schedule(&self) -> Schedule {
        self.schedule
    }

    /// Enqueue a round of jobs. Static: round-robin over workers (block
    /// `i` → worker `i % W`, matching the deterministic split MATLAB's
    /// `spmd` codistributor would make). Dynamic: per-job shared deques,
    /// drained round-robin across jobs.
    pub fn push_round(&self, jobs: Vec<Job>) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "push after close");
        match self.schedule {
            Schedule::Static => {
                let w = st.per_worker.len();
                for (i, job) in jobs.into_iter().enumerate() {
                    st.per_worker[i % w].push_back(job);
                }
            }
            Schedule::Dynamic => {
                for job in jobs {
                    st.push_shared(job);
                }
            }
        }
        drop(st);
        self.cond.notify_all();
    }

    /// Blocking pop for `worker`. Returns `None` once the queue is closed
    /// and empty (for this worker).
    pub fn pop(&self, worker: usize) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.per_worker[worker].pop_front() {
                return Some(job);
            }
            if let Some(job) = st.pop_shared() {
                return Some(job);
            }
            if st.closed {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// The block-processing job `worker` would receive next, without
    /// taking it — `(job id, block index)`, or `None` when the queue is
    /// empty or the next message is a ping/retire. This is a **hint**
    /// for the worker's read-ahead path: under dynamic scheduling
    /// another worker may take the peeked block first, in which case
    /// the prefetched buffer is simply discarded.
    pub fn peek_next(&self, worker: usize) -> Option<(JobId, usize)> {
        let st = self.state.lock().unwrap();
        let job = st.per_worker[worker].front().or_else(|| {
            st.next_rotation_idx()
                .and_then(|i| st.rotation.get(i))
                .and_then(|id| st.shared.get(id))
                .and_then(VecDeque::front)
        })?;
        match job.payload {
            JobPayload::Step { .. } | JobPayload::Assign { .. } | JobPayload::Local { .. } => {
                Some((job.job, job.block))
            }
            JobPayload::Ping | JobPayload::Retire { .. } => None,
        }
    }

    /// Re-enqueue one block mid-round (the retry path). Routed the
    /// same way `push_round` originally placed it so retried work
    /// obeys the schedule's placement contract: static → the block's
    /// round-robin owner `block % workers` (behind any control
    /// messages already queued for it, since workers drain their own
    /// deque first), dynamic → the job's shared deque.
    pub fn push_retry(&self, job: Job) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "push after close");
        match self.schedule {
            Schedule::Static => {
                let w = st.per_worker.len();
                let owner = job.block % w;
                st.per_worker[owner].push_back(job);
            }
            Schedule::Dynamic => st.push_shared(job),
        }
        drop(st);
        self.cond.notify_all();
    }

    /// Enqueue a job for one specific worker (barrier pings, job
    /// retirements), regardless of schedule mode.
    pub fn push_to_worker(&self, worker: usize, job: Job) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "push after close");
        st.per_worker[worker].push_back(job);
        drop(st);
        self.cond.notify_all();
    }

    /// Tag `job` as a member of share group `group` for rotation
    /// affinity. Call before the job's first `push_round` — the tag
    /// only influences where the id *enters* the rotation.
    pub fn set_job_group(&self, job: JobId, group: u64) {
        self.state.lock().unwrap().groups.insert(job, group);
    }

    /// Drop `job`'s share-group tag and QoS priority (job retired or
    /// purged).
    pub fn drop_job_group(&self, job: JobId) {
        let mut st = self.state.lock().unwrap();
        st.groups.remove(&job);
        st.priorities.remove(&job);
    }

    /// Tag `job` with a QoS priority (higher drains first; untagged =
    /// 0). Call alongside `set_job_group`, before the job's first
    /// `push_round`.
    pub fn set_job_priority(&self, job: JobId, priority: usize) {
        if priority > 0 {
            self.state.lock().unwrap().priorities.insert(job, priority);
        }
    }

    /// Remove every queued (not yet popped) job belonging to `job`.
    /// Returns how many were removed — the leader subtracts them from
    /// its expected-outcome count when cancelling or failing a job.
    /// In-flight blocks (already popped) still produce outcomes.
    pub fn purge_job(&self, job: JobId) -> usize {
        let mut st = self.state.lock().unwrap();
        let mut removed = 0;
        if let Some(q) = st.shared.remove(&job) {
            removed += q.len();
        }
        st.rotation.retain(|&id| id != job);
        st.groups.remove(&job);
        st.priorities.remove(&job);
        for q in &mut st.per_worker {
            let before = q.len();
            q.retain(|j| j.job != job);
            removed += before - q.len();
        }
        removed
    }

    /// Close the queue; workers drain what remains and exit.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Jobs currently waiting (for tests / introspection).
    pub fn pending(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.shared.values().map(VecDeque::len).sum::<usize>()
            + st.per_worker.iter().map(VecDeque::len).sum::<usize>()
    }

    /// High water of distinct jobs simultaneously queued in the shared
    /// pool (pool instrumentation; see the admission tests).
    pub fn max_jobs_interleaved(&self) -> usize {
        self.state.lock().unwrap().max_jobs_interleaved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::JobPayload;
    use std::sync::Arc;

    fn job(block: usize) -> Job {
        tagged(0, block)
    }

    fn tagged(id: JobId, block: usize) -> Job {
        Job {
            job: id,
            block,
            round: 0,
            payload: JobPayload::Step {
                centroids: Arc::new(vec![0.0; 6]),
                drift: None,
            },
        }
    }

    #[test]
    fn static_round_robin_assignment() {
        let q = JobQueue::new(2, Schedule::Static);
        q.push_round((0..5).map(job).collect());
        // worker 0 gets blocks 0,2,4; worker 1 gets 1,3
        assert_eq!(q.pop(0).unwrap().block, 0);
        assert_eq!(q.pop(0).unwrap().block, 2);
        assert_eq!(q.pop(1).unwrap().block, 1);
        assert_eq!(q.pop(0).unwrap().block, 4);
        assert_eq!(q.pop(1).unwrap().block, 3);
        q.close();
        assert!(q.pop(0).is_none());
        assert!(q.pop(1).is_none());
    }

    #[test]
    fn push_retry_respects_schedule_placement() {
        // Static: the retried block goes back to its round-robin owner.
        let q = JobQueue::new(2, Schedule::Static);
        q.push_round((0..2).map(job).collect());
        assert_eq!(q.pop(1).unwrap().block, 1);
        q.push_retry(job(1));
        assert_eq!(q.pop(1).unwrap().block, 1, "block 1 belongs to worker 1");
        // Dynamic: back into the job's shared deque, any worker takes it.
        let q = JobQueue::new(2, Schedule::Dynamic);
        q.push_retry(tagged(7, 3));
        let j = q.pop(0).unwrap();
        assert_eq!((j.job, j.block), (7, 3));
    }

    #[test]
    fn dynamic_any_worker_drains() {
        let q = JobQueue::new(3, Schedule::Dynamic);
        q.push_round((0..4).map(job).collect());
        let mut got: Vec<usize> = (0..4).map(|i| q.pop(i % 3).unwrap().block).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn dynamic_interleaves_jobs_round_robin() {
        let q = JobQueue::new(1, Schedule::Dynamic);
        q.push_round((0..3).map(|b| tagged(1, b)).collect());
        q.push_round((0..3).map(|b| tagged(2, b)).collect());
        let order: Vec<(JobId, usize)> = (0..6).map(|_| q.pop(0).map(|j| (j.job, j.block)).unwrap()).collect();
        // strict 1↔2 alternation, blocks in order within each job
        assert_eq!(
            order,
            vec![(1, 0), (2, 0), (1, 1), (2, 1), (1, 2), (2, 2)]
        );
        assert_eq!(q.max_jobs_interleaved(), 2);
    }

    #[test]
    fn priority_job_drains_before_the_rotation() {
        // Job 2 is tagged priority 5; jobs 1 and 3 ride at the default.
        // Every pop must hand out job 2 while it has work queued, then
        // the remainder falls back to the fair 1↔3 alternation.
        let q = JobQueue::new(1, Schedule::Dynamic);
        q.set_job_priority(2, 5);
        q.push_round((0..2).map(|b| tagged(1, b)).collect());
        q.push_round((0..2).map(|b| tagged(2, b)).collect());
        q.push_round((0..2).map(|b| tagged(3, b)).collect());
        assert_eq!(q.peek_next(0), Some((2, 0)));
        let order: Vec<(JobId, usize)> =
            (0..6).map(|_| q.pop(0).map(|j| (j.job, j.block)).unwrap()).collect();
        assert_eq!(
            order,
            vec![(2, 0), (2, 1), (1, 0), (3, 0), (1, 1), (3, 1)]
        );
    }

    #[test]
    fn equal_priorities_keep_the_fair_interleave() {
        // Tagging every job with the same non-zero priority must not
        // perturb the round-robin order.
        let q = JobQueue::new(1, Schedule::Dynamic);
        q.set_job_priority(1, 3);
        q.set_job_priority(2, 3);
        q.push_round((0..2).map(|b| tagged(1, b)).collect());
        q.push_round((0..2).map(|b| tagged(2, b)).collect());
        let order: Vec<JobId> = (0..4).map(|_| q.pop(0).unwrap().job).collect();
        assert_eq!(order, vec![1, 2, 1, 2]);
    }

    #[test]
    fn purge_clears_the_priority_tag() {
        let q = JobQueue::new(1, Schedule::Dynamic);
        q.set_job_priority(2, 9);
        q.push_round((0..1).map(|b| tagged(2, b)).collect());
        q.purge_job(2);
        // Re-submitted work under the same id starts back at default
        // priority, so job 1 (pushed first) pops first.
        q.push_round((0..1).map(|b| tagged(1, b)).collect());
        q.push_round((0..1).map(|b| tagged(2, b)).collect());
        assert_eq!(q.pop(0).unwrap().job, 1);
    }

    #[test]
    fn grouped_jobs_stay_adjacent_in_rotation() {
        // Jobs 1 and 3 share an image (group 7); job 2 is unrelated.
        // The rotation must visit the siblings back-to-back —
        // (1,b),(3,b) pairs — instead of interleaving job 2 between
        // them, so the shared tile for block b stays hot.
        let q = JobQueue::new(1, Schedule::Dynamic);
        q.set_job_group(1, 7);
        q.set_job_group(3, 7);
        q.push_round((0..2).map(|b| tagged(1, b)).collect());
        q.push_round((0..2).map(|b| tagged(2, b)).collect());
        q.push_round((0..2).map(|b| tagged(3, b)).collect());
        let order: Vec<(JobId, usize)> =
            (0..6).map(|_| q.pop(0).map(|j| (j.job, j.block)).unwrap()).collect();
        assert_eq!(
            order,
            vec![(1, 0), (3, 0), (2, 0), (1, 1), (3, 1), (2, 1)]
        );
        // purge drops the group tag; a re-queued sibling falls back to
        // plain rotation order.
        q.purge_job(1);
        q.drop_job_group(3);
        q.push_round(vec![tagged(2, 9)]);
        q.push_round(vec![tagged(3, 9)]);
        let order: Vec<JobId> = (0..2).map(|_| q.pop(0).unwrap().job).collect();
        assert_eq!(order, vec![2, 3]);
    }

    #[test]
    fn purge_removes_only_the_tagged_job() {
        let q = JobQueue::new(2, Schedule::Dynamic);
        q.push_round((0..4).map(|b| tagged(1, b)).collect());
        q.push_round((0..2).map(|b| tagged(2, b)).collect());
        assert_eq!(q.purge_job(1), 4);
        assert_eq!(q.pending(), 2);
        let mut left: Vec<JobId> = (0..2).map(|_| q.pop(0).unwrap().job).collect();
        left.sort_unstable();
        assert_eq!(left, vec![2, 2]);
    }

    #[test]
    fn purge_covers_static_per_worker_queues() {
        let q = JobQueue::new(2, Schedule::Static);
        q.push_round((0..4).map(|b| tagged(1, b)).collect());
        q.push_round((0..4).map(|b| tagged(2, b)).collect());
        assert_eq!(q.purge_job(2), 4);
        assert_eq!(q.pending(), 4);
    }

    #[test]
    fn peek_reports_without_taking() {
        let q = JobQueue::new(2, Schedule::Dynamic);
        assert_eq!(q.peek_next(0), None);
        q.push_round(vec![tagged(3, 7), tagged(3, 8)]);
        assert_eq!(q.peek_next(0), Some((3, 7)));
        assert_eq!(q.peek_next(1), Some((3, 7))); // still there
        assert_eq!(q.pop(0).unwrap().block, 7);
        assert_eq!(q.peek_next(0), Some((3, 8)));
        // static per-worker queues are peeked first
        let qs = JobQueue::new(2, Schedule::Static);
        qs.push_round((0..2).map(job).collect());
        assert_eq!(qs.peek_next(0), Some((0, 0)));
        assert_eq!(qs.peek_next(1), Some((0, 1)));
        // pings are not block work
        qs.push_to_worker(
            0,
            Job {
                job: 9,
                block: usize::MAX,
                round: 0,
                payload: JobPayload::Ping,
            },
        );
        qs.pop(0).unwrap();
        qs.pop(1).unwrap();
        assert_eq!(qs.peek_next(0), None);
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = Arc::new(JobQueue::new(1, Schedule::Dynamic));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop(0).map(|j| j.block));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push_round(vec![job(7)]);
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q = Arc::new(JobQueue::new(2, Schedule::Dynamic));
        let handles: Vec<_> = (0..2)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.pop(w).is_none())
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        for h in handles {
            assert!(h.join().unwrap(), "worker should see closed queue");
        }
    }

    #[test]
    #[should_panic(expected = "push after close")]
    fn push_after_close_panics() {
        let q = JobQueue::new(1, Schedule::Dynamic);
        q.close();
        q.push_round(vec![job(0)]);
    }

    #[test]
    fn schedule_parses() {
        assert_eq!("static".parse::<Schedule>().unwrap(), Schedule::Static);
        assert_eq!("Dynamic".parse::<Schedule>().unwrap(), Schedule::Dynamic);
        assert!("rr".parse::<Schedule>().is_err());
    }
}
