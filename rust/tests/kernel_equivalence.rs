//! Property tests for the kernel layer's headline guarantee: the pruned,
//! fused, lane-vectorized (SoA), and native-SIMD kernels produce
//! **bit-identical** labels, centroids, and counts to the naive kernel —
//! across random images, `k ∈ {1, 2, 4, 8}`, channel widths covering
//! every dispatch path (and every lane-tail residue), every supported
//! `SimdLevel` including the `Portable` fallback, and the paper's three
//! block shapes through the real coordinator under both schedules.
//! (The opt-in `--fma` mode is *not* bit-identical by design — its
//! tolerance contract lives in `tests/simd_tolerance.rs`.)

use std::sync::Arc;

use blockms::blocks::BlockShape;
use blockms::coordinator::{
    ClusterConfig, Coordinator, CoordinatorConfig, Schedule,
};
use blockms::image::SyntheticOrtho;
use blockms::kmeans::kernel::{self, KernelChoice, PrunedState};
use blockms::kmeans::{math, KMeansConfig, SeqKMeans};
use blockms::plan::ExecPlan;
use blockms::util::prng::Rng;
use blockms::util::qcheck::{choice_of, forall, pair, usize_in, Gen};

const KS: [usize; 4] = [1, 2, 4, 8];

fn counts_of(labels: &[u32], k: usize) -> Vec<u64> {
    let mut counts = vec![0u64; k];
    for &l in labels {
        counts[l as usize] += 1;
    }
    counts
}

/// Generator for a random flat pixel buffer: (n_pixels, channels, seed).
struct PixelGen;

impl Gen for PixelGen {
    type Value = (usize, usize, u64);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.range_usize(16, 900);
        // hit the 1/3/4 specializations and the generic fallback
        let channels = [1, 2, 3, 4, 5][rng.range_usize(0, 5)];
        (n, channels, rng.next_u64())
    }
}

fn pixels(n: usize, channels: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * channels).map(|_| rng.next_f32() * 255.0).collect()
}

#[test]
fn prop_seq_kernels_bit_identical() {
    let gen = pair(PixelGen, choice_of(&KS));
    forall(201, 60, &gen, |((n, channels, seed), k)| {
        let px = pixels(*n, *channels, *seed);
        let cfg = KMeansConfig {
            k: *k,
            seed: seed ^ 0x5EED,
            ..Default::default()
        };
        // convergence-driven drive
        let naive = SeqKMeans::run_with(&px, *channels, &cfg, KernelChoice::Naive);
        for kc in [
            KernelChoice::Pruned,
            KernelChoice::Fused,
            KernelChoice::Lanes,
            KernelChoice::Simd,
        ] {
            let other = SeqKMeans::run_with(&px, *channels, &cfg, kc);
            if other.labels != naive.labels
                || other.centroids != naive.centroids
                || other.iterations != naive.iterations
                || other.inertia != naive.inertia
                || counts_of(&other.labels, *k) != counts_of(&naive.labels, *k)
            {
                return false;
            }
        }
        // fixed-iteration drive (the bench mirror)
        let naive = SeqKMeans::run_fixed_iters_with(&px, *channels, &cfg, 5, KernelChoice::Naive);
        for kc in [
            KernelChoice::Pruned,
            KernelChoice::Fused,
            KernelChoice::Lanes,
            KernelChoice::Simd,
        ] {
            let other = SeqKMeans::run_fixed_iters_with(&px, *channels, &cfg, 5, kc);
            if other.labels != naive.labels || other.centroids != naive.centroids {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_pruned_step_accum_bit_identical_across_rounds() {
    let gen = pair(PixelGen, choice_of(&KS));
    forall(202, 80, &gen, |((n, channels, seed), k)| {
        let px = pixels(*n, *channels, *seed);
        let mut cen = pixels(*k, *channels, seed.wrapping_mul(31) + 7);
        let mut state = PrunedState::new();
        let mut drift = None;
        for _ in 0..6 {
            let want = math::step(&px, &cen, *k, *channels);
            let got = kernel::step_pruned(&px, &cen, *k, *channels, &mut state, drift.as_ref());
            if got != want {
                return false;
            }
            let prev = cen.clone();
            math::update_centroids(&want, &mut cen, 0.0);
            drift = Some(kernel::drift_between(&prev, &cen, *k, *channels));
        }
        // fused final labeling vs the naive full pass
        let mut fused_labels = Vec::new();
        let fused_inertia = kernel::assign_pruned(
            &px,
            &cen,
            *k,
            *channels,
            &mut state,
            drift.as_ref(),
            &mut fused_labels,
        );
        let mut naive_labels = Vec::new();
        let naive_inertia = math::assign_all(&px, &cen, *k, *channels, &mut naive_labels);
        fused_labels == naive_labels && fused_inertia == naive_inertia
    });
}

/// The lanes kernel's SoA rounds mirror the interleaved pruned rounds
/// bit for bit: identical accumulators every round, identical final
/// labels/inertia, at every lane-tail residue qcheck finds.
#[test]
fn prop_lanes_step_accum_bit_identical_across_rounds() {
    use blockms::kmeans::tile::SoaTile;
    let gen = pair(PixelGen, choice_of(&KS));
    forall(205, 80, &gen, |((n, channels, seed), k)| {
        let px = pixels(*n, *channels, *seed);
        let tile = SoaTile::from_interleaved(&px, *channels);
        let mut cen = pixels(*k, *channels, seed.wrapping_mul(37) + 11);
        let mut state = PrunedState::new();
        let mut drift = None;
        for _ in 0..6 {
            let want = math::step(&px, &cen, *k, *channels);
            let got = kernel::step_lanes(&tile, &cen, *k, &mut state, drift.as_ref());
            if got != want {
                return false;
            }
            let prev = cen.clone();
            math::update_centroids(&want, &mut cen, 0.0);
            drift = Some(kernel::drift_between(&prev, &cen, *k, *channels));
        }
        let mut lanes_labels = Vec::new();
        let lanes_inertia =
            kernel::assign_lanes(&tile, &cen, *k, &mut state, drift.as_ref(), &mut lanes_labels);
        let mut naive_labels = Vec::new();
        let naive_inertia = math::assign_all(&px, &cen, *k, *channels, &mut naive_labels);
        lanes_labels == naive_labels && lanes_inertia == naive_inertia
    });
}

/// The simd kernel's SoA rounds mirror the interleaved pruned rounds bit
/// for bit at every capability level this host can execute — the
/// `Portable` fallback (the library default the coordinator tests run
/// at) and whatever native level detection resolves to.
#[test]
fn prop_simd_step_accum_bit_identical_at_every_level() {
    use blockms::kmeans::tile::SoaTile;
    use blockms::kmeans::{SimdLevel, SimdMode};
    let mut modes = vec![SimdMode::default()];
    let detected = SimdMode {
        level: SimdLevel::detect(),
        fma: false,
    };
    if detected.level != SimdLevel::Portable {
        modes.push(detected);
    }
    for mode in modes {
        let gen = pair(PixelGen, choice_of(&KS));
        forall(206, 60, &gen, |((n, channels, seed), k)| {
            let px = pixels(*n, *channels, *seed);
            let tile = SoaTile::from_interleaved(&px, *channels);
            let mut cen = pixels(*k, *channels, seed.wrapping_mul(41) + 13);
            let mut state = PrunedState::new();
            let mut drift = None;
            for _ in 0..6 {
                let want = math::step(&px, &cen, *k, *channels);
                let got = kernel::step_simd(&tile, &cen, *k, &mut state, drift.as_ref(), mode);
                if got != want {
                    return false;
                }
                let prev = cen.clone();
                math::update_centroids(&want, &mut cen, 0.0);
                drift = Some(kernel::drift_between(&prev, &cen, *k, *channels));
            }
            let mut simd_labels = Vec::new();
            let simd_inertia = kernel::assign_simd(
                &tile,
                &cen,
                *k,
                &mut state,
                drift.as_ref(),
                &mut simd_labels,
                mode,
            );
            let mut naive_labels = Vec::new();
            let naive_inertia = math::assign_all(&px, &cen, *k, *channels, &mut naive_labels);
            simd_labels == naive_labels && simd_inertia == naive_inertia
        });
    }
}

/// The paper's three block shapes, random sizes, random worker counts:
/// the coordinator must produce bit-identical output under every kernel
/// and both schedules (dynamic scheduling migrates blocks between
/// workers, exercising the state-invalidation fallback).
#[test]
fn prop_coordinator_kernels_identical_across_paper_shapes() {
    let gen = pair(usize_in(16, 64), usize_in(0, 999));
    forall(203, 10, &gen, |&(side, salt)| {
        let (h, w) = (side, side + salt % 9);
        let img = Arc::new(
            SyntheticOrtho::default()
                .with_seed(salt as u64 + 1)
                .generate(h, w),
        );
        let shapes = [
            BlockShape::Rows {
                band_rows: 1 + salt % 13,
            },
            BlockShape::Cols {
                band_cols: 1 + salt % 11,
            },
            BlockShape::Square {
                side: 2 + salt % 17,
            },
        ];
        let ccfg = ClusterConfig {
            k: KS[salt % KS.len()],
            max_iters: 8,
            ..Default::default()
        };
        for shape in shapes {
            let naive = Coordinator::new(CoordinatorConfig {
                exec: ExecPlan::pinned(shape).with_workers(1 + salt % 4),
                ..Default::default()
            })
            .cluster(&img, &ccfg)
            .unwrap();
            for kernel in [
                KernelChoice::Pruned,
                KernelChoice::Fused,
                KernelChoice::Lanes,
                KernelChoice::Simd,
            ] {
                for schedule in [Schedule::Static, Schedule::Dynamic] {
                    let out = Coordinator::new(CoordinatorConfig {
                        exec: ExecPlan::pinned(shape)
                            .with_workers(1 + salt % 4)
                            .with_kernel(kernel),
                        schedule,
                        ..Default::default()
                    })
                    .cluster(&img, &ccfg)
                    .unwrap();
                    if out.labels != naive.labels
                        || out.centroids != naive.centroids
                        || out.iterations != naive.iterations
                        || out.inertia_trace != naive.inertia_trace
                        || counts_of(&out.labels, ccfg.k) != counts_of(&naive.labels, ccfg.k)
                    {
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// Tie-heavy data (integer grid, duplicated centres via duplicated
/// pixels) must not let the pruning path diverge from naive
/// lowest-index tie-breaking.
#[test]
fn prop_kernels_identical_under_distance_ties() {
    forall(204, 40, &usize_in(1, 500), |&salt| {
        let mut rng = Rng::new(salt as u64);
        let n = 120 + salt % 60;
        // integer-valued pixels from a 3-level grid: exact ties abound
        let px: Vec<f32> = (0..n * 3)
            .map(|_| rng.range_usize(0, 3) as f32 * 8.0)
            .collect();
        let cfg = KMeansConfig {
            k: 4,
            seed: salt as u64,
            ..Default::default()
        };
        let naive = SeqKMeans::run_with(&px, 3, &cfg, KernelChoice::Naive);
        [
            KernelChoice::Pruned,
            KernelChoice::Fused,
            KernelChoice::Lanes,
            KernelChoice::Simd,
        ]
        .into_iter()
        .all(|kc| {
            let r = SeqKMeans::run_with(&px, 3, &cfg, kc);
            r.labels == naive.labels && r.centroids == naive.centroids
        })
    });
}
