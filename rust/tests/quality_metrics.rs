//! Known-answer and property tests for `metrics::quality`.
//!
//! The in-module unit tests check qualitative behaviour (ordering,
//! degenerate cases); these tests pin the metrics to **hand-computed
//! values** on tiny fixtures, and use the `util::qcheck` harness to
//! check permutation invariances on generated clusterings.

use blockms::metrics::quality::{adjusted_rand_sampled, davies_bouldin, label_agreement, purity};
use blockms::util::prng::Rng;
use blockms::util::qcheck::{forall, pair, usize_in, vec_of};

// ---------------------------------------------------------------------
// Known answers (worked by hand)
// ---------------------------------------------------------------------

/// 1-channel, two clusters: pixels {0,2} around centroid 1 and {8,10}
/// around centroid 9. Scatter_i = mean |x−c| = 1 for both; centroid
/// distance = 8; R_01 = (1+1)/8 = 0.25; DB = mean(max_j R) = 0.25.
#[test]
fn davies_bouldin_hand_computed_1d() {
    let pixels = vec![0.0f32, 2.0, 8.0, 10.0];
    let labels = vec![0u32, 0, 1, 1];
    let centroids = vec![1.0f32, 9.0];
    let db = davies_bouldin(&pixels, &labels, &centroids, 2, 1);
    assert!((db - 0.25).abs() < 1e-12, "db = {db}");
}

/// 2-channel version: cluster 0 at (0,0),(0,2) → centroid (0,1), cluster
/// 1 at (4,0),(4,2) → centroid (4,1). Scatter = 1 each, distance 4,
/// DB = 2/4 = 0.5.
#[test]
fn davies_bouldin_hand_computed_2d() {
    let pixels = vec![0.0f32, 0.0, 0.0, 2.0, 4.0, 0.0, 4.0, 2.0];
    let labels = vec![0u32, 0, 1, 1];
    let centroids = vec![0.0f32, 1.0, 4.0, 1.0];
    let db = davies_bouldin(&pixels, &labels, &centroids, 2, 2);
    assert!((db - 0.5).abs() < 1e-12, "db = {db}");
}

/// Three clusters on a line: centroids 0, 4, 20, all scatters 1.
/// R matrix: R(0,1)=2/4=0.5, R(0,2)=2/20=0.1, R(1,2)=2/16=0.125.
/// Per-cluster maxima: 0.5, 0.5, 0.125 → DB = 1.125/3 = 0.375.
#[test]
fn davies_bouldin_hand_computed_three_clusters() {
    let pixels = vec![-1.0f32, 1.0, 3.0, 5.0, 19.0, 21.0];
    let labels = vec![0u32, 0, 1, 1, 2, 2];
    let centroids = vec![0.0f32, 4.0, 20.0];
    let db = davies_bouldin(&pixels, &labels, &centroids, 3, 1);
    assert!((db - 0.375).abs() < 1e-12, "db = {db}");
}

/// labels [0,0,1,1,1] vs truth [0,1,1,1,2]: cluster 0 sees truth {0,1}
/// (majority 1 pixel), cluster 1 sees truth {1,1,2} (majority 2 pixels)
/// → purity = (1+2)/5 = 0.6.
#[test]
fn purity_hand_computed() {
    let labels = vec![0u32, 0, 1, 1, 1];
    let truth = vec![0u32, 1, 1, 1, 2];
    assert_eq!(purity(&labels, &truth), 0.6);
}

/// a=[0,0,1,2], b=[1,1,0,0], k=3. Overlaps: (0→1)=2, (1→0)=1, (2→0)=1.
/// Greedy matching: (0,1) worth 2, then (1,0) worth 1; cluster 2 has no
/// unused b-cluster with overlap. Agreement = 3/4.
#[test]
fn label_agreement_hand_computed() {
    let a = vec![0u32, 0, 1, 2];
    let b = vec![1u32, 1, 0, 0];
    assert_eq!(label_agreement(&a, &b, 3), 0.75);
}

/// ARI on two 2-cluster partitions of 6 points that disagree on one
/// point: a=[0,0,0,1,1,1], b=[0,0,1,1,1,1].
/// Contingency: n00=2, n01=1, n11=3. Σcomb2(nij)=1+0+3=4;
/// Σcomb2(rows)=3+3=6; Σcomb2(cols)=1+6=7; comb2(6)=15.
/// expected=6*7/15=2.8; max=6.5; ARI=(4−2.8)/(6.5−2.8)=12/37.
#[test]
fn adjusted_rand_hand_computed() {
    let a = vec![0u32, 0, 0, 1, 1, 1];
    let b = vec![0u32, 0, 1, 1, 1, 1];
    let ari = adjusted_rand_sampled(&a, &b, 6);
    assert!((ari - 12.0 / 37.0).abs() < 1e-12, "ari = {ari}");
}

// ---------------------------------------------------------------------
// Permutation-invariance properties (qcheck)
// ---------------------------------------------------------------------

/// Deterministically derange a permutation of 0..k from a seed.
fn permutation(k: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut p: Vec<u32> = (0..k as u32).collect();
    // Fisher–Yates with the crate PRNG
    for i in (1..k).rev() {
        let j = rng.range_usize(0, i + 1);
        p.swap(i, j);
    }
    p
}

/// Purity counts in u64 — relabeling clusters must leave it *exactly*
/// unchanged.
#[test]
fn purity_is_invariant_under_label_permutation() {
    let gen = pair(vec_of(usize_in(0, 3), 8, 64), usize_in(0, u64::MAX as usize / 2));
    forall(11, 200, &gen, |(raw, pseed)| {
        let labels: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        // truth: a fixed striping of the same length
        let truth: Vec<u32> = (0..labels.len()).map(|i| (i % 3) as u32).collect();
        let p = permutation(4, *pseed as u64);
        let permuted: Vec<u32> = labels.iter().map(|&l| p[l as usize]).collect();
        purity(&labels, &truth) == purity(&permuted, &truth)
    });
}

/// Relabeling one side of `label_agreement` by a permutation of the
/// *partition itself* must score 1.0 (the greedy matcher recovers the
/// bijection exactly).
#[test]
fn label_agreement_recovers_any_permutation() {
    let gen = pair(vec_of(usize_in(0, 4), 5, 80), usize_in(0, 1 << 30));
    forall(12, 300, &gen, |(raw, pseed)| {
        let a: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        let p = permutation(5, *pseed as u64);
        let b: Vec<u32> = a.iter().map(|&l| p[l as usize]).collect();
        label_agreement(&a, &b, 5) == 1.0
    });
}

/// ARI is invariant (up to f64 summation noise) under relabeling either
/// side, and equals 1 for identical partitions.
#[test]
fn adjusted_rand_is_permutation_invariant() {
    let gen = pair(vec_of(usize_in(0, 3), 10, 120), usize_in(0, 1 << 30));
    forall(13, 200, &gen, |(raw, pseed)| {
        let a: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        let truth: Vec<u32> = (0..a.len()).map(|i| ((i * 7) % 4) as u32).collect();
        let p = permutation(4, *pseed as u64);
        let permuted: Vec<u32> = a.iter().map(|&l| p[l as usize]).collect();
        let base = adjusted_rand_sampled(&a, &truth, a.len());
        let perm = adjusted_rand_sampled(&permuted, &truth, a.len());
        (base - perm).abs() < 1e-9
            && (adjusted_rand_sampled(&a, &a, a.len()) - 1.0).abs() < 1e-12
    });
}

/// Davies–Bouldin is invariant (up to f64 reassociation) under a
/// consistent permutation of labels *and* centroid rows.
#[test]
fn davies_bouldin_is_invariant_under_consistent_relabeling() {
    let k = 3usize;
    let gen = pair(vec_of(usize_in(0, k - 1), 6, 48), usize_in(0, 1 << 30));
    forall(14, 200, &gen, |(raw, pseed)| {
        let labels: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        // deterministic 1-channel pixels spread by index
        let pixels: Vec<f32> = (0..labels.len())
            .map(|i| (i as f32 * 1.37) % 29.0)
            .collect();
        let centroids = vec![3.0f32, 11.0, 23.0];
        let p = permutation(k, *pseed as u64);
        let plabels: Vec<u32> = labels.iter().map(|&l| p[l as usize]).collect();
        // permute centroid rows to match: new row p[j] holds old row j
        let mut pcen = vec![0.0f32; k];
        for j in 0..k {
            pcen[p[j] as usize] = centroids[j];
        }
        let base = davies_bouldin(&pixels, &labels, &centroids, k, 1);
        let perm = davies_bouldin(&pixels, &plabels, &pcen, k, 1);
        (base - perm).abs() < 1e-9
    });
}
