//! Known-answer and property tests for `metrics::quality`.
//!
//! The in-module unit tests check qualitative behaviour (ordering,
//! degenerate cases); these tests pin the metrics to **hand-computed
//! values** on tiny fixtures, and use the `util::qcheck` harness to
//! check permutation invariances on generated clusterings.

use blockms::kmeans::InitMethod;
use blockms::metrics::quality::{adjusted_rand_sampled, davies_bouldin, label_agreement, purity};
use blockms::sweep::{knee_index, SweepReport, SweepVariant, VariantResult};
use blockms::util::prng::Rng;
use blockms::util::qcheck::{forall, pair, usize_in, vec_of};

// ---------------------------------------------------------------------
// Known answers (worked by hand)
// ---------------------------------------------------------------------

/// 1-channel, two clusters: pixels {0,2} around centroid 1 and {8,10}
/// around centroid 9. Scatter_i = mean |x−c| = 1 for both; centroid
/// distance = 8; R_01 = (1+1)/8 = 0.25; DB = mean(max_j R) = 0.25.
#[test]
fn davies_bouldin_hand_computed_1d() {
    let pixels = vec![0.0f32, 2.0, 8.0, 10.0];
    let labels = vec![0u32, 0, 1, 1];
    let centroids = vec![1.0f32, 9.0];
    let db = davies_bouldin(&pixels, &labels, &centroids, 2, 1);
    assert!((db - 0.25).abs() < 1e-12, "db = {db}");
}

/// 2-channel version: cluster 0 at (0,0),(0,2) → centroid (0,1), cluster
/// 1 at (4,0),(4,2) → centroid (4,1). Scatter = 1 each, distance 4,
/// DB = 2/4 = 0.5.
#[test]
fn davies_bouldin_hand_computed_2d() {
    let pixels = vec![0.0f32, 0.0, 0.0, 2.0, 4.0, 0.0, 4.0, 2.0];
    let labels = vec![0u32, 0, 1, 1];
    let centroids = vec![0.0f32, 1.0, 4.0, 1.0];
    let db = davies_bouldin(&pixels, &labels, &centroids, 2, 2);
    assert!((db - 0.5).abs() < 1e-12, "db = {db}");
}

/// Three clusters on a line: centroids 0, 4, 20, all scatters 1.
/// R matrix: R(0,1)=2/4=0.5, R(0,2)=2/20=0.1, R(1,2)=2/16=0.125.
/// Per-cluster maxima: 0.5, 0.5, 0.125 → DB = 1.125/3 = 0.375.
#[test]
fn davies_bouldin_hand_computed_three_clusters() {
    let pixels = vec![-1.0f32, 1.0, 3.0, 5.0, 19.0, 21.0];
    let labels = vec![0u32, 0, 1, 1, 2, 2];
    let centroids = vec![0.0f32, 4.0, 20.0];
    let db = davies_bouldin(&pixels, &labels, &centroids, 3, 1);
    assert!((db - 0.375).abs() < 1e-12, "db = {db}");
}

/// labels [0,0,1,1,1] vs truth [0,1,1,1,2]: cluster 0 sees truth {0,1}
/// (majority 1 pixel), cluster 1 sees truth {1,1,2} (majority 2 pixels)
/// → purity = (1+2)/5 = 0.6.
#[test]
fn purity_hand_computed() {
    let labels = vec![0u32, 0, 1, 1, 1];
    let truth = vec![0u32, 1, 1, 1, 2];
    assert_eq!(purity(&labels, &truth), 0.6);
}

/// a=[0,0,1,2], b=[1,1,0,0], k=3. Overlaps: (0→1)=2, (1→0)=1, (2→0)=1.
/// Greedy matching: (0,1) worth 2, then (1,0) worth 1; cluster 2 has no
/// unused b-cluster with overlap. Agreement = 3/4.
#[test]
fn label_agreement_hand_computed() {
    let a = vec![0u32, 0, 1, 2];
    let b = vec![1u32, 1, 0, 0];
    assert_eq!(label_agreement(&a, &b, 3), 0.75);
}

/// ARI on two 2-cluster partitions of 6 points that disagree on one
/// point: a=[0,0,0,1,1,1], b=[0,0,1,1,1,1].
/// Contingency: n00=2, n01=1, n11=3. Σcomb2(nij)=1+0+3=4;
/// Σcomb2(rows)=3+3=6; Σcomb2(cols)=1+6=7; comb2(6)=15.
/// expected=6*7/15=2.8; max=6.5; ARI=(4−2.8)/(6.5−2.8)=12/37.
#[test]
fn adjusted_rand_hand_computed() {
    let a = vec![0u32, 0, 0, 1, 1, 1];
    let b = vec![0u32, 0, 1, 1, 1, 1];
    let ari = adjusted_rand_sampled(&a, &b, 6);
    assert!((ari - 12.0 / 37.0).abs() < 1e-12, "ari = {ari}");
}

// ---------------------------------------------------------------------
// Permutation-invariance properties (qcheck)
// ---------------------------------------------------------------------

/// Deterministically derange a permutation of 0..k from a seed.
fn permutation(k: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut p: Vec<u32> = (0..k as u32).collect();
    // Fisher–Yates with the crate PRNG
    for i in (1..k).rev() {
        let j = rng.range_usize(0, i + 1);
        p.swap(i, j);
    }
    p
}

/// Purity counts in u64 — relabeling clusters must leave it *exactly*
/// unchanged.
#[test]
fn purity_is_invariant_under_label_permutation() {
    let gen = pair(vec_of(usize_in(0, 3), 8, 64), usize_in(0, u64::MAX as usize / 2));
    forall(11, 200, &gen, |(raw, pseed)| {
        let labels: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        // truth: a fixed striping of the same length
        let truth: Vec<u32> = (0..labels.len()).map(|i| (i % 3) as u32).collect();
        let p = permutation(4, *pseed as u64);
        let permuted: Vec<u32> = labels.iter().map(|&l| p[l as usize]).collect();
        purity(&labels, &truth) == purity(&permuted, &truth)
    });
}

/// Relabeling one side of `label_agreement` by a permutation of the
/// *partition itself* must score 1.0 (the greedy matcher recovers the
/// bijection exactly).
#[test]
fn label_agreement_recovers_any_permutation() {
    let gen = pair(vec_of(usize_in(0, 4), 5, 80), usize_in(0, 1 << 30));
    forall(12, 300, &gen, |(raw, pseed)| {
        let a: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        let p = permutation(5, *pseed as u64);
        let b: Vec<u32> = a.iter().map(|&l| p[l as usize]).collect();
        label_agreement(&a, &b, 5) == 1.0
    });
}

/// ARI is invariant (up to f64 summation noise) under relabeling either
/// side, and equals 1 for identical partitions.
#[test]
fn adjusted_rand_is_permutation_invariant() {
    let gen = pair(vec_of(usize_in(0, 3), 10, 120), usize_in(0, 1 << 30));
    forall(13, 200, &gen, |(raw, pseed)| {
        let a: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        let truth: Vec<u32> = (0..a.len()).map(|i| ((i * 7) % 4) as u32).collect();
        let p = permutation(4, *pseed as u64);
        let permuted: Vec<u32> = a.iter().map(|&l| p[l as usize]).collect();
        let base = adjusted_rand_sampled(&a, &truth, a.len());
        let perm = adjusted_rand_sampled(&permuted, &truth, a.len());
        (base - perm).abs() < 1e-9
            && (adjusted_rand_sampled(&a, &a, a.len()) - 1.0).abs() < 1e-12
    });
}

// ---------------------------------------------------------------------
// Sweep model selection: elbow / ranking on a known-k fixture
// ---------------------------------------------------------------------

/// The known-k fixture: 8 one-channel pixels in **three** well-separated
/// groups — A = {0, 2}, B = {10, 12}, C = {28, 30, 32, 34}.
fn known_k3_pixels() -> Vec<f32> {
    vec![0.0, 2.0, 10.0, 12.0, 28.0, 30.0, 32.0, 34.0]
}

/// Build one sweep row from a hand-specified assignment of the fixture:
/// DB comes from the real `davies_bouldin`, inertia is worked by hand
/// in the caller.
fn fixture_row(k: usize, labels: &[u32], centroids: &[f32], inertia: f64) -> VariantResult {
    let pixels = known_k3_pixels();
    VariantResult {
        variant: SweepVariant {
            k,
            seed: 1,
            init: InitMethod::RandomSample,
        },
        iterations: 3,
        inertia,
        db_index: davies_bouldin(&pixels, labels, centroids, k, 1),
        wall_secs: 0.0,
    }
}

/// The three candidate partitions, each at its k-optimal assignment.
///
/// k=2 (merge A∪B): centroids {6, 31}; scatters (6+4+4+6)/4 = 5 and
///   (3+1+1+3)/4 = 2; distance 25 → DB = 7/25 = **0.28**.
///   Inertia = 36+16+16+36 + 9+1+1+9 = **124**.
/// k=3 (the truth): centroids {1, 11, 31}; scatters {1, 1, 2};
///   R01 = 2/10, R02 = 3/30, R12 = 3/20 → maxima {0.2, 0.2, 0.15}
///   → DB = 0.55/3 = **0.18333…** (the minimum).
///   Inertia = 1·4 + (9+1+1+9) = **24**.
/// k=4 (split C): centroids {1, 11, 29, 33}; scatters all 1;
///   R23 = 2/4 = 0.5 dominates both halves → maxima
///   {0.2, 0.2, 0.5, 0.5} → DB = 1.4/4 = **0.35**.
///   Inertia = 1·8 = **8**.
fn known_k3_report() -> SweepReport {
    SweepReport {
        rows: vec![
            fixture_row(2, &[0, 0, 0, 0, 1, 1, 1, 1], &[6.0, 31.0], 124.0),
            fixture_row(3, &[0, 0, 1, 1, 2, 2, 2, 2], &[1.0, 11.0, 31.0], 24.0),
            fixture_row(4, &[0, 0, 1, 1, 2, 2, 3, 3], &[1.0, 11.0, 29.0, 33.0], 8.0),
        ],
    }
}

/// The DB indices behind the report are exactly the hand-worked values.
#[test]
fn known_k_fixture_db_indices_are_hand_computed() {
    let report = known_k3_report();
    assert!((report.rows[0].db_index - 0.28).abs() < 1e-12);
    assert!((report.rows[1].db_index - 0.55 / 3.0).abs() < 1e-12);
    assert!((report.rows[2].db_index - 0.35).abs() < 1e-12);
}

/// DB ranking puts the true k first: undersplit (k=2) inflates scatter,
/// oversplit (k=4) pulls centroids together — both lose to k=3.
#[test]
fn report_ranks_true_k_first_by_db_minimum() {
    let report = known_k3_report();
    let ranked = report.ranked_by_db();
    assert_eq!(report.rows[ranked[0]].variant.k, 3, "true k must win");
    assert_eq!(report.best().unwrap().variant.k, 3);
    // and the full order is k3 < k2 < k4
    let order: Vec<usize> = ranked.iter().map(|&i| report.rows[i].variant.k).collect();
    assert_eq!(order, vec![3, 2, 4]);
}

/// Knee detection agrees: inertia 124 → 24 → 8 over ks {2, 3, 4}
/// normalizes to y = {0, 0.862…, 1} at x = {0, ½, 1}; the sag |x − y|
/// peaks at the middle point, so the knee is k = 3.
#[test]
fn report_knee_detects_true_k_on_the_inertia_elbow() {
    let report = known_k3_report();
    let (ks, inertia) = report.elbow();
    assert_eq!(ks, vec![2, 3, 4]);
    assert_eq!(inertia, vec![124.0, 24.0, 8.0]);
    assert_eq!(knee_index(&inertia), 1);
    assert_eq!(report.knee_k(), Some(3));
}

/// A bitwise DB tie (the same assignment scored twice under different
/// nominal k) breaks toward the smaller k — the simpler model.
#[test]
fn db_tie_breaks_to_the_simpler_model() {
    let base = fixture_row(3, &[0, 0, 1, 1, 2, 2, 2, 2], &[1.0, 11.0, 31.0], 24.0);
    let mut alias = base.clone();
    alias.variant.k = 5; // same score, larger claimed k
    let report = SweepReport {
        rows: vec![alias, base],
    };
    assert_eq!(report.rows[0].db_index.to_bits(), report.rows[1].db_index.to_bits());
    assert_eq!(report.best().unwrap().variant.k, 3);
}

/// A degenerate fit (every pixel in one cluster → DB collapses to 0.0)
/// must rank *last*, never winning on its artificially perfect score.
#[test]
fn degenerate_collapse_ranks_last_not_first() {
    let degenerate = fixture_row(2, &[0; 8], &[18.5, 0.0], 1030.0);
    assert_eq!(degenerate.db_index, 0.0, "one non-empty cluster → 0.0");
    assert!(degenerate.is_degenerate());
    let honest = fixture_row(3, &[0, 0, 1, 1, 2, 2, 2, 2], &[1.0, 11.0, 31.0], 24.0);
    let report = SweepReport {
        rows: vec![degenerate, honest],
    };
    let ranked = report.ranked_by_db();
    assert_eq!(ranked, vec![1, 0]);
    assert_eq!(report.best().unwrap().variant.k, 3);
}

/// Property: on a piecewise-linear curve with exactly one bend (steep
/// drop, then shallow), `knee_index` recovers the bend — the distance
/// to the chord is linear on each segment, so its maximum sits at the
/// only interior breakpoint.
#[test]
fn knee_index_recovers_a_single_bend_exactly() {
    let gen = pair(usize_in(4, 10), usize_in(0, 1 << 20));
    forall(15, 300, &gen, |&(n, bseed)| {
        let bend = 1 + bseed % (n - 2); // interior index in 1..n-2
        let mut v = 1000.0f64;
        let mut values = vec![v];
        for i in 1..n {
            v -= if i <= bend { 100.0 } else { 1.0 };
            values.push(v);
        }
        knee_index(&values) == bend
    });
}

/// Davies–Bouldin is invariant (up to f64 reassociation) under a
/// consistent permutation of labels *and* centroid rows.
#[test]
fn davies_bouldin_is_invariant_under_consistent_relabeling() {
    let k = 3usize;
    let gen = pair(vec_of(usize_in(0, k - 1), 6, 48), usize_in(0, 1 << 30));
    forall(14, 200, &gen, |(raw, pseed)| {
        let labels: Vec<u32> = raw.iter().map(|&v| v as u32).collect();
        // deterministic 1-channel pixels spread by index
        let pixels: Vec<f32> = (0..labels.len())
            .map(|i| (i as f32 * 1.37) % 29.0)
            .collect();
        let centroids = vec![3.0f32, 11.0, 23.0];
        let p = permutation(k, *pseed as u64);
        let plabels: Vec<u32> = labels.iter().map(|&l| p[l as usize]).collect();
        // permute centroid rows to match: new row p[j] holds old row j
        let mut pcen = vec![0.0f32; k];
        for j in 0..k {
            pcen[p[j] as usize] = centroids[j];
        }
        let base = davies_bouldin(&pixels, &labels, &centroids, k, 1);
        let perm = davies_bouldin(&pixels, &plabels, &pcen, k, 1);
        (base - perm).abs() < 1e-9
    });
}
