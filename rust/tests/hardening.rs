//! Liveness-hardening integration tests: speculative re-execution
//! bit-identity across kernels × block shapes, watchdog escalation of
//! silently hung workers, per-job deadlines that checkpoint and resume,
//! QoS priority shedding under overload, and graceful drain.
//!
//! The acceptance bar everywhere is *bitwise* equality with an
//! unhardened fault-free run: the watchdog, speculation, deadlines, and
//! drain may change when work happens and who does it — never a label,
//! a centroid byte, or the inertia bits.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use blockms::blocks::BlockShape;
use blockms::coordinator::{
    run_fingerprint, ClusterConfig, ClusterMode, ClusterOutput, Coordinator, CoordinatorConfig,
    Schedule,
};
use blockms::image::{Raster, SyntheticOrtho};
use blockms::kmeans::kernel::KernelChoice;
use blockms::plan::ExecPlan;
use blockms::resilience::{Checkpoint, FaultKind, FaultPlan, DEFAULT_HEARTBEAT_TIMEOUT_MS};
use blockms::service::{ClusterServer, JobSpec, JobStatus, ServerConfig};

fn scene(h: usize, w: usize, seed: u64) -> Arc<Raster> {
    Arc::new(SyntheticOrtho::default().with_seed(seed).generate(h, w))
}

/// Per-test unique checkpoint path (tests in this binary run in
/// parallel; the pid guards against stale files from other runs).
fn ckpt_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("blockms_hard_p{}_{tag}.ckpt", std::process::id()))
}

fn assert_bitwise_eq(got: &ClusterOutput, want: &ClusterOutput, ctx: &str) {
    assert_eq!(got.labels, want.labels, "{ctx}: labels diverged");
    assert_eq!(got.centroids, want.centroids, "{ctx}: centroids diverged");
    assert_eq!(
        got.inertia.to_bits(),
        want.inertia.to_bits(),
        "{ctx}: inertia diverged"
    );
    assert_eq!(got.iterations, want.iterations, "{ctx}: iteration count diverged");
}

/// Speculation is a pure availability knob: re-running straggler blocks
/// on idle workers (first result wins) must be bitwise invisible across
/// every kernel × block-shape cell, under both schedules.
#[test]
fn speculative_runs_are_bit_identical_across_kernels_and_shapes() {
    let img = scene(48, 40, 23);
    let ccfg = ClusterConfig {
        k: 3,
        fixed_iters: Some(5),
        seed: 7,
        ..Default::default()
    };
    let cells: &[(KernelChoice, BlockShape)] = &[
        (KernelChoice::Naive, BlockShape::Rows { band_rows: 11 }),
        (KernelChoice::Pruned, BlockShape::Cols { band_cols: 13 }),
        (KernelChoice::Lanes, BlockShape::Square { side: 13 }),
    ];
    for (kernel, shape) in cells {
        for schedule in [Schedule::Static, Schedule::Dynamic] {
            let ctx = format!("{kernel:?}/{shape:?}/{schedule:?}");
            let exec = ExecPlan::pinned(*shape).with_workers(3).with_kernel(*kernel);
            let solo = Coordinator::new(CoordinatorConfig {
                exec,
                schedule,
                ..Default::default()
            })
            .cluster(&img, &ccfg)
            .unwrap();
            let speculative = Coordinator::new(CoordinatorConfig {
                exec: exec.with_speculate(true),
                schedule,
                ..Default::default()
            })
            .cluster(&img, &ccfg)
            .unwrap();
            assert_bitwise_eq(&speculative, &solo, &ctx);
        }
    }
}

/// A worker that silently hangs far past the heartbeat timeout is
/// escalated by the watchdog and its block re-queued under the retry
/// budget: the run completes bit-identically in time bounded by the
/// heartbeat timeout — not by the (much longer) hang.
#[test]
fn hung_worker_is_escalated_within_the_heartbeat_bound() {
    let img = scene(40, 36, 31);
    let ccfg = ClusterConfig {
        k: 2,
        fixed_iters: Some(3),
        seed: 9,
        ..Default::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Rows { band_rows: 9 }).with_workers(3);
    let reference = Coordinator::new(CoordinatorConfig {
        exec,
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();
    // A one-minute park: if recovery depended on the hang releasing,
    // this test could not finish inside its bound.
    let hang_ms = 60_000;
    let t0 = Instant::now();
    let recovered = Coordinator::new(CoordinatorConfig {
        exec: exec.with_retries(1),
        fault: Some(FaultPlan::new(1, FaultKind::Hang { ms: hang_ms }, 1)),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();
    let elapsed = t0.elapsed();
    assert_bitwise_eq(&recovered, &reference, "watchdog-recovered hang");
    assert!(
        elapsed < Duration::from_millis(hang_ms / 2),
        "recovery took {elapsed:?} — bounded by the hang, not the {}ms heartbeat timeout",
        DEFAULT_HEARTBEAT_TIMEOUT_MS
    );
}

/// With a zero retry budget the watchdog has nowhere to re-queue an
/// escalated block: the run must fail loudly, naming the stalled round,
/// block, and worker — never hang the caller.
#[test]
fn hang_with_zero_retries_stalls_loudly() {
    let img = scene(36, 32, 37);
    let ccfg = ClusterConfig {
        k: 2,
        fixed_iters: Some(3),
        seed: 3,
        ..Default::default()
    };
    let t0 = Instant::now();
    let err = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Rows { band_rows: 9 }).with_workers(2),
        fault: Some(FaultPlan::new(1, FaultKind::Hang { ms: 60_000 }, 1)),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap_err();
    let elapsed = t0.elapsed();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("stalled") && msg.contains("no heartbeat"),
        "stall error must name the silent worker, got: {msg}"
    );
    assert!(
        elapsed < Duration::from_secs(30),
        "the stall error took {elapsed:?} — the caller must not wait out the hang"
    );
}

/// A per-job deadline stops the run at the next round boundary with a
/// checkpoint, and resuming from it finishes bit-identically to an
/// undisturbed twin — a deadline costs time, never values.
#[test]
fn deadline_checkpoints_then_resumes_bit_identically() {
    let img = scene(40, 32, 41);
    let ccfg = ClusterConfig {
        k: 2,
        fixed_iters: Some(5),
        seed: 13,
        ..Default::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Square { side: 11 }).with_workers(2);
    let reference = Coordinator::new(CoordinatorConfig {
        exec,
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();
    let path = ckpt_path("deadline_resume");
    let _ = std::fs::remove_file(&path);
    // A 30ms hang in round 1 makes the round deterministically outlive
    // the 1ms deadline, so the run always stops with rounds left.
    let err = Coordinator::new(CoordinatorConfig {
        exec: exec.with_deadline_ms(1),
        fault: Some(FaultPlan::new(1, FaultKind::Hang { ms: 30 }, 1)),
        checkpoint: Some(path.clone()),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("deadline") && msg.contains("resume"),
        "deadline error must say it is resumable, got: {msg}"
    );
    assert!(path.exists(), "the deadline must leave a checkpoint behind");
    let resumed = Coordinator::new(CoordinatorConfig {
        exec,
        resume: Some(path.clone()),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();
    assert_bitwise_eq(&resumed, &reference, "deadline checkpoint-then-resume");
    let _ = std::fs::remove_file(&path);
}

fn service_spec(img: &Arc<Raster>, seed: u64, fixed_iters: Option<usize>) -> JobSpec {
    JobSpec::new(
        Arc::clone(img),
        ExecPlan::pinned(BlockShape::Square { side: 10 }),
        ClusterConfig {
            k: 2,
            seed,
            fixed_iters,
            ..Default::default()
        },
    )
}

/// Under overload, `try_submit` sheds strictly by priority: an offer
/// that outranks nothing is turned away, and a higher-priority offer
/// preempts the lowest-priority open job — never an equal or higher one.
#[test]
fn overload_sheds_strictly_lowest_priority_first() {
    let img = scene(32, 28, 43);
    let server = ClusterServer::start(ServerConfig {
        workers: 2,
        schedule: Schedule::Dynamic,
        max_in_flight: 1,
    });
    // A mid-priority squatter that cannot finish on its own.
    let squatter = server
        .try_submit(service_spec(&img, 1, Some(1_000_000)).with_priority(3))
        .unwrap()
        .expect("empty gate admits");
    // Equal priority does not preempt: turned away.
    assert!(
        server
            .try_submit(service_spec(&img, 2, None).with_priority(3))
            .unwrap()
            .is_none(),
        "equal priority must not preempt"
    );
    // Lower priority certainly does not.
    assert!(
        server
            .try_submit(service_spec(&img, 3, None).with_priority(1))
            .unwrap()
            .is_none(),
        "lower priority must not preempt"
    );
    // Higher priority preempts the squatter and runs to completion.
    let high = server
        .try_submit(service_spec(&img, 4, None).with_priority(5))
        .unwrap()
        .expect("higher priority preempts the squatter");
    let out = high.wait_output().expect("preempting job completes");
    assert_eq!(out.labels.len(), 32 * 28);
    assert_eq!(squatter.wait(), JobStatus::Cancelled, "the squatter was shed");
    let stats = server.stats();
    assert_eq!(stats.shed, 3, "two turn-aways and one preemption");
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.cancelled, 1);
    server.shutdown();
}

/// Drain loses no admitted job: finished work stays published, and a
/// job that cannot finish inside the budget is checkpointed at its last
/// round boundary and reported — then the checkpoint actually resumes.
#[test]
fn drain_checkpoints_unfinished_jobs_and_loses_none() {
    let img = scene(32, 28, 47);
    let reference = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 10 }).with_workers(2),
        schedule: Schedule::Dynamic,
        ..Default::default()
    })
    .cluster(
        &img,
        &ClusterConfig {
            k: 2,
            seed: 47,
            fixed_iters: Some(4),
            ..Default::default()
        },
    )
    .unwrap();
    let server = ClusterServer::start(ServerConfig {
        workers: 2,
        schedule: Schedule::Dynamic,
        max_in_flight: 2,
    });
    let quick = server
        .submit(service_spec(&img, 47, Some(4)))
        .unwrap();
    let quick_out = quick.wait_output().expect("quick job finishes before the drain");
    assert_bitwise_eq(&quick_out, &reference, "served job vs solo twin");
    let ckpt = ckpt_path("drain_none_lost");
    let _ = std::fs::remove_file(&ckpt);
    // A job that cannot finish on its own: the drain must checkpoint it.
    let stuck = server
        .submit(service_spec(&img, 48, Some(1_000_000)).with_deadline_checkpoint(ckpt.clone()))
        .unwrap();
    let report = server.drain(Duration::from_millis(2_000));
    let status = stuck.wait();
    match &status {
        JobStatus::Deadline { checkpoint: Some(p) } => {
            assert_eq!(p, &ckpt, "drain honors the job's checkpoint path");
            assert!(p.exists(), "the drain checkpoint file must exist");
        }
        other => panic!("stuck job should drain to a checkpoint, got {other:?}"),
    }
    let stuck_disp = report
        .dispositions
        .iter()
        .find(|(id, _)| *id == stuck.id())
        .map(|(_, d)| d.clone())
        .expect("the open job appears in the drain report");
    assert!(
        stuck_disp.contains("checkpointed") && stuck_disp.contains("resumable"),
        "disposition must point at the checkpoint, got: {stuck_disp}"
    );
    // The checkpoint is live: it loads and carries the exact fingerprint
    // of the interrupted configuration, so a real resume would accept it.
    // (Actually resuming would run the remaining million rounds.)
    let ck = Checkpoint::load(&ckpt).expect("drain checkpoint loads");
    let want = run_fingerprint(
        32,
        28,
        3,
        &ClusterConfig {
            k: 2,
            seed: 48,
            fixed_iters: Some(1_000_000),
            ..Default::default()
        },
        ClusterMode::Global,
    );
    assert_eq!(ck.fingerprint, want, "checkpoint is keyed to the drained job's config");
    let _ = std::fs::remove_file(&ckpt);
}
