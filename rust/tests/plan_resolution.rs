//! Property suite for the ExecPlan planner, plus the solo/service
//! config-drift regression test.
//!
//! The contracts under test:
//!
//! 1. **Pins are law** — a fully-pinned request resolves to exactly its
//!    pins, for every combination qcheck can draw (the planner never
//!    overrides an explicit choice).
//! 2. **No regret under its own model** — the pick's predicted cost is
//!    ≤ every candidate it rejected, verified against an *independent*
//!    exhaustive enumeration over shapes × kernels × layouts (× cache ×
//!    prefetch) that re-asks the cost model directly.
//! 3. **Determinism** — the same request and priors always produce the
//!    same plan and the same candidate ordering.
//! 4. **Solo/service identity** — identical inputs resolve to identical
//!    `ExecPlan`s on both paths, and running that plan solo vs through
//!    the service yields bit-identical output (the drift hazard the
//!    refactor was built to kill).

use std::sync::Arc;

use blockms::blocks::{ApproachKind, BlockPlan, BlockShape};
use blockms::coordinator::{ClusterConfig, Coordinator, CoordinatorConfig};
use blockms::image::SyntheticOrtho;
use blockms::kmeans::kernel::KernelChoice;
use blockms::kmeans::tile::TileLayout;
use blockms::plan::{ExecPlan, Planner, PlanRequest};
use blockms::service::{ClusterServer, JobSpec, ServerConfig};
use blockms::util::prng::Rng;
use blockms::util::qcheck::{forall, pair, usize_in, Gen};

/// Generator for a random workload geometry the planner accepts.
struct GeomGen;

impl Gen for GeomGen {
    type Value = (usize, usize, usize, usize, usize);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (
            rng.range_usize(32, 2048),      // height
            rng.range_usize(32, 2048),      // width
            [1, 3, 4][rng.range_usize(0, 3)], // channels
            rng.range_usize(1, 12),         // k
            rng.range_usize(1, 12),         // rounds
        )
    }
}

/// Generator for a full set of pins.
struct PinGen;

impl Gen for PinGen {
    type Value = ExecPlan;
    fn generate(&self, rng: &mut Rng) -> ExecPlan {
        let shape = match rng.range_usize(0, 4) {
            0 => BlockShape::Rows {
                band_rows: rng.range_usize(1, 500),
            },
            1 => BlockShape::Cols {
                band_cols: rng.range_usize(1, 500),
            },
            2 => BlockShape::Square {
                side: rng.range_usize(1, 500),
            },
            _ => BlockShape::Custom {
                rows: rng.range_usize(1, 400),
                cols: rng.range_usize(1, 400),
            },
        };
        let kernel = KernelChoice::ALL[rng.range_usize(0, KernelChoice::ALL.len())];
        let layout = [TileLayout::Interleaved, TileLayout::Soa][rng.range_usize(0, 2)];
        ExecPlan::pinned(shape)
            .with_workers(rng.range_usize(1, 16))
            .with_kernel(kernel)
            .with_layout(layout)
            .with_arena_mb(rng.range_usize(0, 512))
            .with_prefetch(rng.range_usize(0, 2) == 1)
            .with_strip_cache(rng.range_usize(0, 64))
    }
}

fn request(geom: &(usize, usize, usize, usize, usize), strip_rows: Option<usize>) -> PlanRequest {
    let &(h, w, c, k, rounds) = geom;
    PlanRequest::new(h, w, c, k)
        .with_rounds(rounds)
        .with_strip_rows(strip_rows)
}

#[test]
fn prop_fully_pinned_plan_round_trips_unchanged() {
    let gen = pair(GeomGen, PinGen);
    forall(301, 120, &gen, |(geom, pins)| {
        let strip_rows = if pins.strip_cache > 0 { Some(32) } else { None };
        let req = request(geom, strip_rows).pin_all(pins);
        assert!(req.fully_pinned());
        let (resolved, explain) = Planner::default().resolve(&req);
        resolved == *pins && explain.candidates.len() == 1
    });
}

#[test]
fn prop_pick_is_no_regret_vs_exhaustive_enumeration() {
    let gen = pair(GeomGen, usize_in(0, 2));
    forall(302, 60, &gen, |(geom, strips)| {
        let strip_rows = match strips {
            0 => None,
            1 => Some(32),
            _ => Some(64),
        };
        let req = request(geom, strip_rows);
        let planner = Planner::default();
        let (picked, explain) = planner.resolve(&req);
        let w = req.workload();
        // Independent exhaustive enumeration: every shape × kernel ×
        // layout × cache × prefetch the request admits, costed straight
        // off the model (not through Explain).
        let shapes: Vec<BlockShape> = ApproachKind::ALL
            .iter()
            .map(|&a| BlockShape::paper_default(a, req.height, req.width))
            .collect();
        let caches: Vec<usize> = match strip_rows {
            Some(_) => vec![0, w.unique_strips()],
            None => vec![0],
        };
        let prefetches: Vec<bool> = match strip_rows {
            Some(_) => vec![false, true],
            None => vec![false],
        };
        let picked_plan = BlockPlan::new(req.height, req.width, picked.shape);
        let picked_cost = planner.model().predict(
            &w,
            &picked_plan,
            picked.kernel,
            picked.layout,
            picked.workers,
            picked.strip_cache,
            picked.prefetch,
        );
        for shape in shapes {
            let plan = BlockPlan::new(req.height, req.width, shape);
            for kernel in KernelChoice::ALL {
                for layout in [TileLayout::Interleaved, TileLayout::Soa] {
                    for &cache in &caches {
                        for &prefetch in &prefetches {
                            let cost = planner.model().predict(
                                &w,
                                &plan,
                                kernel,
                                layout,
                                picked.workers,
                                cache,
                                prefetch,
                            );
                            if cost.wall_secs < picked_cost.wall_secs {
                                return false; // the planner left time on the table
                            }
                        }
                    }
                }
            }
        }
        // And the explain report agrees with itself.
        explain.ranked()[0].plan == picked
    });
}

#[test]
fn prop_resolution_is_deterministic() {
    forall(303, 60, &GeomGen, |geom| {
        let req = request(geom, Some(64));
        let (a, ea) = Planner::default().resolve(&req);
        let (b, eb) = Planner::default().resolve(&req);
        a == b
            && ea.chosen == eb.chosen
            && ea
                .candidates
                .iter()
                .zip(&eb.candidates)
                .all(|(x, y)| x.plan == y.plan && x.cost == y.cost)
    });
}

/// The config-drift regression test: the solo coordinator and the
/// service resolve identical plans from identical inputs — because both
/// consume the SAME resolved `ExecPlan` — and produce bit-identical
/// output under it.
#[test]
fn solo_and_service_resolve_identical_plans_and_outputs() {
    let (h, w, k) = (48, 44, 3);
    let img = Arc::new(SyntheticOrtho::default().with_seed(77).generate(h, w));

    // Identical inputs → identical resolution on both paths (resolution
    // is a pure function of the request; nothing path-specific leaks in).
    let req = PlanRequest::new(h, w, img.channels(), k).with_rounds(6);
    let (exec_solo, _) = Planner::default().resolve(&req);
    let (exec_service, _) = Planner::default().resolve(&req);
    assert_eq!(
        exec_solo, exec_service,
        "solo and service must resolve identical plans from identical inputs"
    );

    let ccfg = ClusterConfig {
        k,
        seed: 78,
        ..Default::default()
    };
    let solo = Coordinator::new(CoordinatorConfig {
        exec: exec_solo,
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();

    let server = ClusterServer::start(ServerConfig {
        workers: exec_service.workers,
        ..Default::default()
    });
    let spec = JobSpec::new(Arc::clone(&img), exec_service, ccfg);
    // The spec's derived tiling is the solo coordinator's tiling.
    assert_eq!(spec.block_plan().len(), solo.blocks);
    let served = server.submit(spec).unwrap().wait_output().unwrap();
    server.shutdown();

    assert_eq!(solo.labels, served.labels, "labels drifted between paths");
    assert_eq!(solo.centroids, served.centroids);
    assert_eq!(solo.inertia.to_bits(), served.inertia.to_bits());
    assert_eq!(solo.iterations, served.iterations);
}

/// Auto-planning changes speed knobs only, never values: a planner-
/// resolved plan and the naive pinned baseline produce bit-identical
/// labels and centroids.
#[test]
fn auto_plan_is_bit_identical_to_pinned_baseline() {
    let (h, w, k) = (52, 40, 4);
    let img = Arc::new(SyntheticOrtho::default().with_seed(5).generate(h, w));
    let ccfg = ClusterConfig {
        k,
        seed: 6,
        ..Default::default()
    };
    let shape = BlockShape::paper_default(ApproachKind::Cols, h, w);
    let baseline = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(shape).with_workers(2),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();

    let mut req = PlanRequest::new(h, w, img.channels(), k).with_rounds(8);
    req.shape = Some(shape); // same tiling; kernel/layout left to the planner
    req.workers = Some(2);
    let (exec, _) = Planner::default().resolve(&req);
    let auto = Coordinator::new(CoordinatorConfig {
        exec,
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();
    assert_eq!(auto.labels, baseline.labels);
    assert_eq!(auto.centroids, baseline.centroids);
    assert_eq!(auto.iterations, baseline.iterations);
}
