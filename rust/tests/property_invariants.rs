//! Property tests over the framework's core invariants, using the
//! in-tree `qcheck` mini-harness (proptest is not vendored offline).

use std::sync::Arc;

use blockms::blocks::{BlockPlan, BlockRegion, BlockShape, LabelAssembler};
use blockms::coordinator::{
    ClusterConfig, Coordinator, CoordinatorConfig, Schedule,
};
use blockms::image::SyntheticOrtho;
use blockms::kmeans::math::{self, StepAccum};
use blockms::metrics::Speedup;
use blockms::simtime::{SimBlock, SimParams, WorkerSim};
use blockms::stripstore::{read_amplification, Backing, StripStore};
use blockms::util::json::Json;
use blockms::util::prng::Rng;
use blockms::util::qcheck::{forall, pair, usize_in, Gen};

/// Generator for random (height, width, shape) plan inputs.
struct PlanGen;

impl Gen for PlanGen {
    type Value = (usize, usize, BlockShape);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let h = rng.range_usize(1, 120);
        let w = rng.range_usize(1, 120);
        let shape = match rng.range_usize(0, 4) {
            0 => BlockShape::Rows {
                band_rows: rng.range_usize(1, 50),
            },
            1 => BlockShape::Cols {
                band_cols: rng.range_usize(1, 50),
            },
            2 => BlockShape::Square {
                side: rng.range_usize(1, 50),
            },
            _ => BlockShape::Custom {
                rows: rng.range_usize(1, 50),
                cols: rng.range_usize(1, 50),
            },
        };
        (h, w, shape)
    }
}

#[test]
fn prop_plan_tiles_image_exactly() {
    forall(101, 300, &PlanGen, |&(h, w, shape)| {
        let plan = BlockPlan::new(h, w, shape);
        // total area covers image
        if plan.total_area() != h * w {
            return false;
        }
        // pairwise disjoint
        for (i, a) in plan.regions().iter().enumerate() {
            for b in plan.regions().iter().skip(i + 1) {
                if a.intersects(b) {
                    return false;
                }
            }
        }
        // block_of is consistent
        for row in (0..h).step_by((h / 7).max(1)) {
            for col in (0..w).step_by((w / 7).max(1)) {
                if !plan.region(plan.block_of(row, col)).contains(row, col) {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_partition_then_assemble_is_identity() {
    forall(102, 200, &PlanGen, |&(h, w, shape)| {
        let plan = BlockPlan::new(h, w, shape);
        let mut asm = LabelAssembler::new(h, w);
        for region in plan.iter() {
            let mut labels = Vec::with_capacity(region.area());
            for r in region.row0..region.row_end() {
                for c in region.col0..region.col_end() {
                    labels.push((r * w + c) as u32);
                }
            }
            if asm.place(region, &labels).is_err() {
                return false;
            }
        }
        match asm.finish() {
            Ok(out) => out == (0..(h * w) as u32).collect::<Vec<_>>(),
            Err(_) => false,
        }
    });
}

#[test]
fn prop_strip_reads_match_closed_form() {
    // measured strip reads on a real store == analytic count, any shape
    let gen = pair(PlanGen, usize_in(1, 40));
    forall(103, 60, &gen, |((h, w, shape), strip_rows)| {
        let img = SyntheticOrtho::default()
            .with_seed((h * 131 + w) as u64)
            .generate(*h, *w);
        let plan = BlockPlan::new(*h, *w, *shape);
        let store = StripStore::new(&img, *strip_rows, Backing::Memory).unwrap();
        let mut rd = store.reader().unwrap();
        let mut buf = Vec::new();
        for region in plan.iter() {
            rd.read_block(region, &mut buf).unwrap();
            if buf != img.crop(region) {
                return false; // content must match a direct crop too
            }
        }
        let (expected, _, amp) = read_amplification(&plan, *strip_rows);
        amp >= 1.0 && store.stats().snapshot().strip_reads as usize == expected
    });
}

#[test]
fn prop_step_accum_is_partition_invariant() {
    // splitting a pixel buffer at arbitrary points and merging the
    // per-part accumulators gives the whole-buffer accumulator exactly
    forall(104, 100, &usize_in(2, 400), |&n| {
        let mut rng = Rng::new(n as u64 * 7 + 1);
        let px: Vec<f32> = (0..n * 3).map(|_| rng.next_f32() * 255.0).collect();
        let k = 2 + (n % 3);
        let cen: Vec<f32> = (0..k * 3).map(|_| rng.next_f32() * 255.0).collect();
        let whole = math::step(&px, &cen, k, 3);

        // random 3-way split (on pixel boundaries)
        let a = rng.range_usize(0, n + 1);
        let b = rng.range_usize(a, n + 1);
        let mut merged = StepAccum::zeros(k, 3);
        for part in [&px[..a * 3], &px[a * 3..b * 3], &px[b * 3..]] {
            if !part.is_empty() {
                merged.merge(&math::step(part, &cen, k, 3));
            }
        }
        merged.counts == whole.counts
            && merged
                .sums
                .iter()
                .zip(&whole.sums)
                .all(|(x, y)| (x - y).abs() < 1e-6)
            && (merged.inertia - whole.inertia).abs() < 1e-3
    });
}

#[test]
fn prop_lloyd_inertia_monotone_under_random_data() {
    forall(105, 40, &usize_in(8, 200), |&n| {
        let mut rng = Rng::new(n as u64);
        let px: Vec<f32> = (0..n * 3).map(|_| rng.next_f32() * 255.0).collect();
        let k = 2 + (n % 4).min(2);
        let mut cen: Vec<f32> = px[..k * 3].to_vec();
        let mut prev = f64::INFINITY;
        for _ in 0..6 {
            let acc = math::step(&px, &cen, k, 3);
            if acc.inertia > prev * (1.0 + 1e-7) + 1e-6 {
                return false;
            }
            prev = acc.inertia;
            math::update_centroids(&acc, &mut cen, 0.0);
        }
        true
    });
}

#[test]
fn prop_simtime_bounds_and_monotonicity() {
    forall(106, 150, &usize_in(1, 25), |&nblocks| {
        let mut rng = Rng::new(nblocks as u64 * 13);
        let blocks: Vec<SimBlock> = (0..nblocks)
            .map(|_| SimBlock {
                io_secs: rng.next_f64() * 0.1,
                compute_secs: rng.next_f64(),
            })
            .collect();
        let work: f64 = blocks.iter().map(SimBlock::total).sum();
        let cp = blocks.iter().map(SimBlock::total).fold(0.0, f64::max);
        let mut prev = f64::INFINITY;
        for workers in [1usize, 2, 4, 8] {
            let sim = WorkerSim::new(SimParams {
                workers,
                schedule: Schedule::Dynamic,
                ..Default::default()
            });
            let r = sim.round(&blocks);
            // bounds
            if r.makespan > work + 1e-9 || r.makespan < cp - 1e-9 {
                return false;
            }
            if r.makespan < work / workers as f64 - 1e-9 {
                return false;
            }
            // monotone in workers (dynamic earliest-free, shared disk)
            if r.makespan > prev + 1e-9 {
                return false;
            }
            prev = r.makespan;
        }
        true
    });
}

#[test]
fn prop_speedup_efficiency_algebra() {
    forall(107, 200, &pair(usize_in(1, 1000), usize_in(1, 16)), |&(t, w)| {
        let serial = t as f64 / 100.0 + 0.001;
        let parallel = serial / (1.0 + (w as f64 - 1.0) * 0.7);
        let s = Speedup::compute(serial, parallel);
        let eff = s.efficiency(w);
        (s.0 - serial / parallel).abs() < 1e-12 && (eff - s.0 / w as f64).abs() < 1e-12
    });
}

#[test]
fn prop_global_mode_equals_serial_any_plan() {
    // the headline invariant, across random plans and worker counts
    forall(108, 12, &PlanGen, |&(h, w, shape)| {
        // keep sizes sane for a full clustering run
        let (h, w) = (h.max(8), w.max(8));
        let img = Arc::new(
            SyntheticOrtho::default()
                .with_seed((h + w * 7) as u64)
                .generate(h, w),
        );
        let ccfg = ClusterConfig {
            k: 2,
            max_iters: 6,
            ..Default::default()
        };
        let coord = Coordinator::new(CoordinatorConfig {
            exec: blockms::plan::ExecPlan::pinned(shape).with_workers(1 + (h % 4)),
            ..Default::default()
        });
        let par = coord.cluster(&img, &ccfg).unwrap();
        let seq = coord.serial(&img, &ccfg).unwrap();
        par.labels == seq.labels && par.centroids == seq.centroids
    });
}

#[test]
fn prop_json_display_parse_round_trip() {
    struct JsonGen;
    impl Gen for JsonGen {
        type Value = Json;
        fn generate(&self, rng: &mut Rng) -> Json {
            fn val(rng: &mut Rng, depth: usize) -> Json {
                match rng.range_usize(0, if depth > 2 { 4 } else { 6 }) {
                    0 => Json::Null,
                    1 => Json::Bool(rng.next_f64() < 0.5),
                    2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round() / 8.0),
                    3 => Json::Str(format!("s{}", rng.next_below(1000))),
                    4 => Json::Arr((0..rng.range_usize(0, 4)).map(|_| val(rng, depth + 1)).collect()),
                    _ => Json::Obj(
                        (0..rng.range_usize(0, 4))
                            .map(|i| (format!("k{i}"), val(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            val(rng, 0)
        }
    }
    forall(109, 300, &JsonGen, |j| {
        Json::parse(&j.to_string()).as_ref() == Ok(j)
    });
}

#[test]
fn prop_block_region_contains_iff_in_bounds() {
    forall(110, 300, &pair(usize_in(0, 30), usize_in(1, 30)), |&(o, s)| {
        let r = BlockRegion::new(o, o + 1, s, s + 1);
        r.contains(o, o + 1)
            && r.contains(o + s - 1, o + s + 1)
            && !r.contains(o + s, o + 1)
            && !r.contains(o, o + s + 2)
    });
}
