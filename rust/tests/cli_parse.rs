//! CLI contract tests: parse round-trips for every subcommand, and the
//! binary's exit-code discipline — usage mistakes (unknown flag,
//! unknown subcommand, bad value) exit **2** with a message naming the
//! offender; clean runs exit 0.

use std::process::{Command, Output};

use blockms::cli::{blockms_cli, SUBCOMMANDS};
use blockms::util::cli::CliError;

// ---------------------------------------------------------------------
// Library-level round-trips (the exact spec the binary ships)
// ---------------------------------------------------------------------

#[test]
fn every_subcommand_parses_bare() {
    let cli = blockms_cli();
    for sub in SUBCOMMANDS {
        let args = cli.parse(vec![sub.to_string()]).unwrap();
        assert_eq!(args.subcommand(), Some(*sub), "{sub}");
    }
}

#[test]
fn cluster_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec![
            "cluster", "--k", "8", "--workers", "3", "--kernel", "fused", "--mode", "local",
            "--schedule", "static", "--approach", "row", "--width", "640", "--height=480",
            "--strip-rows", "16", "--serial",
        ])
        .unwrap();
    assert_eq!(args.subcommand(), Some("cluster"));
    assert_eq!(args.get_parse::<usize>("k").unwrap(), 8);
    assert_eq!(args.get_parse::<usize>("workers").unwrap(), 3);
    assert_eq!(args.get("kernel"), Some("fused"));
    assert_eq!(args.get("mode"), Some("local"));
    assert_eq!(args.get("schedule"), Some("static"));
    assert_eq!(args.get("approach"), Some("row"));
    assert_eq!(args.get_parse::<usize>("width").unwrap(), 640);
    assert_eq!(args.get_parse::<usize>("height").unwrap(), 480);
    assert_eq!(args.get_parse::<usize>("strip-rows").unwrap(), 16);
    assert!(args.flag("serial"));
    assert!(!args.flag("verbose"));
}

#[test]
fn layout_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec![
            "cluster", "--kernel", "lanes", "--layout", "soa", "--arena-mb", "64",
            "--strip-cache", "12", "--prefetch",
        ])
        .unwrap();
    assert_eq!(args.get("kernel"), Some("lanes"));
    assert_eq!(args.get("layout"), Some("soa"));
    assert_eq!(args.get_parse::<usize>("arena-mb").unwrap(), 64);
    assert_eq!(args.get_parse::<usize>("strip-cache").unwrap(), 12);
    assert!(args.flag("prefetch"));

    let args = cli.parse(vec!["layout", "--quick", "--out", "l.json"]).unwrap();
    assert_eq!(args.subcommand(), Some("layout"));
    assert!(args.flag("quick"));
    assert_eq!(args.get("out"), Some("l.json"));
}

#[test]
fn service_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec![
            "serve", "--jobs", "12", "--max-in-flight", "5", "--workers", "8",
        ])
        .unwrap();
    assert_eq!(args.subcommand(), Some("serve"));
    assert_eq!(args.get_parse::<usize>("jobs").unwrap(), 12);
    assert_eq!(args.get_parse::<usize>("max-in-flight").unwrap(), 5);

    let args = cli
        .parse(vec!["batch", "--pools", "1,2,4", "--batches", "1,16", "--out", "b.json"])
        .unwrap();
    assert_eq!(args.subcommand(), Some("batch"));
    assert_eq!(args.get("pools"), Some("1,2,4"));
    assert_eq!(args.get("batches"), Some("1,16"));
    assert_eq!(args.get("out"), Some("b.json"));
}

#[test]
fn bench_flags_round_trip() {
    let cli = blockms_cli();
    for (sub, extra) in [
        ("paper-tables", vec!["--table", "12"]),
        ("cases", vec![]),
        ("sweep", vec!["--ks", "2..8", "--seeds", "2", "--out", "s.json"]),
        ("kernels", vec![]),
        ("layout", vec![]),
        ("info", vec![]),
    ] {
        let mut argv = vec![sub, "--scale", "0.1", "--bench-iters", "3", "--seed", "9"];
        argv.extend(extra);
        let args = cli.parse(argv).unwrap();
        assert_eq!(args.subcommand(), Some(sub));
        assert_eq!(args.get_parse::<f64>("scale").unwrap(), 0.1);
        assert_eq!(args.get_parse::<usize>("bench-iters").unwrap(), 3);
        assert_eq!(args.get_parse::<u64>("seed").unwrap(), 9);
    }
}

#[test]
fn sweep_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec![
            "sweep", "--ks", "2,4,8", "--seeds", "3", "--inits", "random,plusplus",
            "--strip-rows", "16", "--workers", "2", "--out", "BS.json",
        ])
        .unwrap();
    assert_eq!(args.subcommand(), Some("sweep"));
    assert_eq!(args.get("ks"), Some("2,4,8"));
    assert_eq!(args.get_parse::<usize>("seeds").unwrap(), 3);
    assert_eq!(args.get("inits"), Some("random,plusplus"));
    assert_eq!(args.get("out"), Some("BS.json"));
    assert!(args.provided("ks"), "typed --ks is a pin");

    // Range syntax and the grid defaults survive a bare parse.
    let args = cli.parse(vec!["sweep", "--ks", "2..8", "--quick"]).unwrap();
    assert_eq!(args.get("ks"), Some("2..8"));
    assert!(args.flag("quick"));
    assert_eq!(args.get("seeds"), Some("1"), "default: one seed replicate");
    assert_eq!(args.get("inits"), Some("random"), "default init axis");
}

#[test]
fn plan_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec!["plan", "--k", "4", "--strip-rows", "64", "--quick", "--verbose"])
        .unwrap();
    assert_eq!(args.subcommand(), Some("plan"));
    assert_eq!(args.get_parse::<usize>("k").unwrap(), 4);
    assert!(args.flag("quick"));
    assert!(args.flag("verbose"));

    let args = cli
        .parse(vec!["cluster", "--auto", "--dry-run", "--kernel", "lanes"])
        .unwrap();
    assert!(args.flag("auto"));
    assert!(args.flag("dry-run"));
    assert!(args.provided("kernel"), "typed --kernel is a pin");
    assert!(!args.provided("approach"), "defaulted --approach is not");
}

#[test]
fn unknown_flag_and_missing_value_are_typed_errors() {
    let cli = blockms_cli();
    assert_eq!(
        cli.parse(vec!["cluster", "--nope"]),
        Err(CliError::Unknown("nope".into()))
    );
    assert_eq!(
        cli.parse(vec!["cluster", "--k"]),
        Err(CliError::MissingValue("k".into()))
    );
    assert_eq!(
        cli.parse(vec!["--help"]),
        Err(CliError::HelpRequested)
    );
}

// ---------------------------------------------------------------------
// Binary-level exit codes (spawning the real executable)
// ---------------------------------------------------------------------

fn run(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_blockms"))
        .args(args)
        .current_dir(std::env::temp_dir())
        .output()
        .expect("spawn blockms")
}

fn assert_usage_error(args: &[&str], names: &str) {
    let out = run(args);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(
        out.status.code(),
        Some(2),
        "{args:?} should exit 2; stderr: {stderr}"
    );
    assert!(
        stderr.contains(names),
        "{args:?} stderr must name {names:?}: {stderr}"
    );
}

#[test]
fn unknown_flag_exits_2_for_every_subcommand() {
    for sub in SUBCOMMANDS {
        assert_usage_error(&[sub, "--definitely-not-a-flag"], "definitely-not-a-flag");
    }
}

#[test]
fn unknown_subcommand_exits_2() {
    assert_usage_error(&["frobnicate"], "frobnicate");
}

#[test]
fn bad_values_exit_2_naming_the_flag() {
    assert_usage_error(&["cluster", "--k", "abc"], "--k");
    // small dims: these reach flag validation after the scene is built
    assert_usage_error(
        &["cluster", "--width", "32", "--height", "32", "--kernel", "turbo"],
        "--kernel",
    );
    assert_usage_error(
        &["cluster", "--width", "32", "--height", "32", "--schedule", "rr"],
        "--schedule",
    );
    assert_usage_error(&["serve", "--jobs", "many"], "--jobs");
    assert_usage_error(&["batch", "--pools", "1,x"], "--pools");
    assert_usage_error(&["batch", "--batches", "0"], "--batches");
    assert_usage_error(&["kernels", "--scale", "big"], "--scale");
    assert_usage_error(&["paper-tables", "--table", "twelve"], "--table");
    assert_usage_error(&["sweep", "--bench-iters", "3.5"], "--bench-iters");
    assert_usage_error(&["cases", "--seed", "-1"], "--seed");
    // Sweep grid syntax: malformed or empty grids are usage errors.
    assert_usage_error(&["sweep", "--ks", "banana"], "--ks");
    assert_usage_error(&["sweep", "--ks", "8..2"], "--ks"); // inverted = empty grid
    assert_usage_error(&["sweep", "--ks", "0..3"], "--ks"); // k=0 invalid
    assert_usage_error(&["sweep", "--seeds", "0"], "--seeds"); // empty seed axis
    assert_usage_error(&["sweep", "--inits", "kohonen"], "--inits");
    // parsed-but-out-of-range values are usage errors too, not panics
    assert_usage_error(&["serve", "--workers", "0"], "--workers");
    assert_usage_error(&["serve", "--max-in-flight", "0"], "--max-in-flight");
    assert_usage_error(&["cluster", "--k", "0"], "--k");
    assert_usage_error(
        &["cluster", "--width", "32", "--height", "32", "--strip-rows", "0"],
        "--strip-rows",
    );
}

#[test]
fn missing_value_exits_2() {
    assert_usage_error(&["cluster", "--k"], "--k");
}

#[test]
fn help_exits_0_and_lists_every_subcommand() {
    let out = run(&["--help"]);
    assert_eq!(out.status.code(), Some(0));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for sub in SUBCOMMANDS {
        assert!(stdout.contains(sub), "help must list {sub}: {stdout}");
    }
}

#[test]
fn info_runs_clean() {
    let out = run(&["info"]);
    assert_eq!(out.status.code(), Some(0));
}

#[test]
fn cluster_happy_path_exits_0() {
    let out = run(&[
        "cluster", "--width", "48", "--height", "40", "--k", "2", "--iters", "2", "--workers",
        "2", "--serial",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("label agreement with serial: 100.0000%"), "{stdout}");
}

#[test]
fn dry_run_resolves_plan_without_pixels_and_exits_0() {
    let out = run(&[
        "cluster", "--width", "4096", "--height", "4096", "--k", "4", "--auto", "--dry-run",
        "--strip-rows", "64",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("plan:"), "{stdout}");
    assert!(stdout.contains("planner:"), "{stdout}");
    // a 4096x4096 scene was never generated
    assert!(!stdout.contains("generating synthetic"), "{stdout}");
}

#[test]
fn plan_subcommand_ranks_candidates_and_exits_0() {
    let out = run(&["plan", "--quick", "--k", "2"]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("ns/px/pass"), "{stdout}");
    assert!(stdout.contains("planner:"), "{stdout}");
}

#[test]
fn auto_cluster_reports_predicted_vs_actual() {
    let out = run(&[
        "cluster", "--width", "48", "--height", "40", "--k", "2", "--iters", "2", "--auto",
        "--serial",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("predicted"), "{stdout}");
    assert!(stdout.contains("actual"), "{stdout}");
    // auto-planning must not change values
    assert!(stdout.contains("label agreement with serial: 100.0000%"), "{stdout}");
}

#[test]
fn serve_happy_path_exits_0() {
    let out = run(&[
        "serve", "--jobs", "3", "--workers", "2", "--max-in-flight", "2", "--width", "40",
        "--height", "32", "--k", "2", "--iters", "2",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("aggregate: 3 jobs"), "{stdout}");
}

#[test]
fn mem_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec!["cluster", "--mem-mb", "64", "--file-backed", "--auto"])
        .unwrap();
    assert_eq!(args.get_parse::<usize>("mem-mb").unwrap(), 64);
    assert!(args.flag("file-backed"));
    assert!(args.provided("mem-mb"));
    let args = cli
        .parse(vec!["stream", "--quick", "--out", "BS.json", "--workers", "2"])
        .unwrap();
    assert_eq!(args.subcommand(), Some("stream"));
    assert!(args.flag("quick"));
}

#[test]
fn mem_mb_zero_is_a_usage_error() {
    assert_usage_error(
        &["cluster", "--mem-mb", "0", "--width", "64", "--height", "64"],
        "mem-mb",
    );
}

#[test]
fn mem_budget_cluster_streams_within_budget() {
    // 384x256x3xf32 = 1.125 MiB of pixels against a 1 MiB budget: the
    // planner must degrade (file backing / strip-row blocks) and the
    // run must report its audited residency.
    let out = run(&[
        "cluster", "--auto", "--mem-mb", "1", "--strip-rows", "16", "--width", "256",
        "--height", "384", "--k", "2", "--iters", "2",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("predicted peak resident"), "{stdout}");
    assert!(stdout.contains("streaming synthetic"), "{stdout}");
    assert!(stdout.contains("within budget"), "{stdout}");
    assert!(!stdout.contains("OVER BUDGET"), "{stdout}");
}

#[test]
fn mem_budget_dry_run_predicts_without_pixels() {
    let out = run(&[
        "cluster", "--auto", "--mem-mb", "1", "--strip-rows", "16", "--width", "256",
        "--height", "384", "--k", "2", "--dry-run",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("predicted peak resident"), "{stdout}");
    assert!(!stdout.contains("streaming"), "dry-run touched pixels: {stdout}");
}

#[test]
fn impossible_budget_fails_with_the_shortfall() {
    // One 64-row strip of a 16384-wide image is 12 MiB by itself: no
    // candidate fits 1 MiB, and the error must say so (exit 1, not a
    // thrashing OOM run).
    let out = run(&[
        "cluster", "--auto", "--mem-mb", "1", "--width", "16384", "--height", "4096", "--k",
        "2", "--dry-run",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("no feasible plan"), "{stderr}");
}

#[test]
fn stream_quick_writes_json() {
    let out_path = std::env::temp_dir().join("blockms_cli_test_BENCH_stream.json");
    let _ = std::fs::remove_file(&out_path);
    let out = run(&["stream", "--quick", "--out", out_path.to_str().unwrap()]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let text = std::fs::read_to_string(&out_path).expect("BENCH_stream.json written");
    assert!(text.contains("matches_in_memory"), "{text}");
    assert!(text.contains("peak_resident_bytes"), "{text}");
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn sweep_quick_writes_json_and_stays_identical() {
    let out_path = std::env::temp_dir().join("blockms_cli_test_BENCH_sweep.json");
    let _ = std::fs::remove_file(&out_path);
    let out = run(&["sweep", "--quick", "--out", out_path.to_str().unwrap()]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("amortized"), "{stdout}");
    let text = std::fs::read_to_string(&out_path).expect("BENCH_sweep.json written");
    assert!(text.contains("\"matches_solo\":true"), "{text}");
    assert!(text.contains("bytes_read_ratio"), "{text}");
    assert!(text.contains("amortized_jobs_per_sec"), "{text}");
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn sweep_grid_overrides_the_quick_axes() {
    let out_path = std::env::temp_dir().join("blockms_cli_test_BENCH_sweep_grid.json");
    let _ = std::fs::remove_file(&out_path);
    let out = run(&[
        "sweep", "--quick", "--ks", "2,3", "--seeds", "2",
        "--out", out_path.to_str().unwrap(),
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let text = std::fs::read_to_string(&out_path).expect("sweep JSON written");
    // 2 ks × 2 seeds × 1 init = 4 variants
    assert!(text.contains("\"variants\":4"), "{text}");
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn batch_happy_path_writes_json() {
    let out_path = std::env::temp_dir().join("blockms_cli_test_BENCH_service.json");
    let _ = std::fs::remove_file(&out_path);
    let out = run(&[
        "batch", "--pools", "1,2", "--batches", "2", "--scale", "0.04", "--bench-iters", "2",
        "--k", "2", "--out", out_path.to_str().unwrap(),
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let text = std::fs::read_to_string(&out_path).expect("BENCH_service.json written");
    assert!(text.contains("speedup_vs_serialized"), "{text}");
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn resilience_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec![
            "cluster", "--retries", "2", "--checkpoint", "run.ckpt", "--checkpoint-every", "3",
            "--fault", "1:panic:1",
        ])
        .unwrap();
    assert_eq!(args.get_parse::<usize>("retries").unwrap(), 2);
    assert_eq!(args.get("checkpoint"), Some("run.ckpt"));
    assert_eq!(args.get_parse::<usize>("checkpoint-every").unwrap(), 3);
    assert_eq!(args.get("fault"), Some("1:panic:1"));
    assert!(args.provided("retries"), "typed --retries is a pin");
    let args = cli.parse(vec!["cluster", "--resume", "run.ckpt"]).unwrap();
    assert_eq!(args.get("resume"), Some("run.ckpt"));
    let args = cli.parse(vec!["resilience", "--quick", "--out", "BR.json"]).unwrap();
    assert_eq!(args.subcommand(), Some("resilience"));
    assert!(args.flag("quick"));
}

#[test]
fn checkpoint_cadence_without_a_path_is_a_usage_error() {
    assert_usage_error(
        &[
            "cluster", "--width", "32", "--height", "32", "--checkpoint-every", "2", "--dry-run",
        ],
        "checkpoint",
    );
}

#[test]
fn malformed_fault_specs_are_usage_errors() {
    for bad in ["x", "1:bogus", "1:error:0", "1:error:1:z", "1:error:1:2:3"] {
        assert_usage_error(
            &["cluster", "--width", "32", "--height", "32", "--fault", bad],
            "--fault",
        );
    }
}

#[test]
fn injected_fault_recovers_under_a_retry_budget_at_the_binary_level() {
    let out = run(&[
        "cluster", "--width", "64", "--height", "64", "--k", "2", "--iters", "2",
        "--fault", "0:error:1", "--retries", "1",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");

    // Zero retries: the same fault is a loud runtime failure (exit 1).
    let out = run(&[
        "cluster", "--width", "64", "--height", "64", "--k", "2", "--iters", "2",
        "--fault", "0:error:1",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("injected failure"), "{stderr}");
}

// ---------------------------------------------------------------------
// Liveness hardening: deadlines, QoS, speculation, drain, hang faults
// ---------------------------------------------------------------------

#[test]
fn hardening_flags_round_trip() {
    let cli = blockms_cli();
    let args = cli
        .parse(vec![
            "cluster", "--deadline-ms", "5000", "--priority", "2", "--speculate",
            "--fault", "1:hang60000", "--retries", "1",
        ])
        .unwrap();
    assert_eq!(args.get_parse::<usize>("deadline-ms").unwrap(), 5000);
    assert_eq!(args.get_parse::<usize>("priority").unwrap(), 2);
    assert!(args.flag("speculate"));
    assert_eq!(args.get("fault"), Some("1:hang60000"));

    let args = cli
        .parse(vec!["serve", "--drain-timeout", "250", "--priority", "1"])
        .unwrap();
    assert_eq!(args.get_parse::<u64>("drain-timeout").unwrap(), 250);

    let args = cli.parse(vec!["hardening", "--quick", "--out", "h.json"]).unwrap();
    assert_eq!(args.subcommand(), Some("hardening"));
    assert!(args.flag("quick"));
    assert_eq!(args.get("out"), Some("h.json"));
}

#[test]
fn bad_hardening_values_exit_2_naming_the_flag() {
    // A hang duration must be a positive integer.
    for bad in ["1:hang0", "1:hangxyz", "1:hang-5"] {
        assert_usage_error(
            &["cluster", "--width", "32", "--height", "32", "--fault", bad],
            "--fault",
        );
    }
    assert_usage_error(&["cluster", "--deadline-ms", "soon"], "--deadline-ms");
    assert_usage_error(&["serve", "--drain-timeout", "abc"], "--drain-timeout");
    assert_usage_error(&["serve", "--priority", "high"], "--priority");
}

#[test]
fn hang_fault_without_retries_is_a_usage_error() {
    // A parked worker with no retry budget can only stall out; the
    // pairing is rejected up front (exit 2), naming the flag.
    assert_usage_error(
        &["cluster", "--width", "32", "--height", "32", "--fault", "1:hang"],
        "--fault",
    );
}

#[test]
fn short_hang_recovers_at_the_binary_level() {
    // A sub-heartbeat hang: the parked worker wakes and computes, the
    // run completes normally under its retry budget (exit 0) — the
    // hang grammar and the speculation flag both ride `cluster`.
    let out = run(&[
        "cluster", "--width", "48", "--height", "40", "--k", "2", "--iters", "2",
        "--fault", "1:hang100", "--retries", "1", "--speculate",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
}

#[test]
fn deadline_checkpoints_and_resumes_at_the_binary_level() {
    let ckpt = std::env::temp_dir().join(format!(
        "blockms_cli_deadline_p{}.ckpt",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&ckpt);
    let ckpt_s = ckpt.to_str().unwrap();
    // A 200ms hang in round 1 makes the round outlive the 1ms deadline
    // deterministically: exit 1, checkpoint written, message says how
    // to resume.
    let out = run(&[
        "cluster", "--width", "40", "--height", "32", "--k", "2", "--iters", "4",
        "--deadline-ms", "1", "--fault", "1:hang200", "--retries", "1",
        "--checkpoint", ckpt_s,
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(1), "stderr: {stderr}");
    assert!(
        stderr.contains("deadline") && stderr.contains("resume"),
        "deadline failure must say it is resumable: {stderr}"
    );
    assert!(ckpt.exists(), "the deadline must leave a checkpoint behind");
    // The checkpoint resumes cleanly to the finished result.
    let out = run(&[
        "cluster", "--width", "40", "--height", "32", "--k", "2", "--iters", "4",
        "--resume", ckpt_s,
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn serve_drains_gracefully_at_the_binary_level() {
    let out = run(&[
        "serve", "--jobs", "2", "--workers", "2", "--max-in-flight", "2", "--k", "2",
        "--width", "48", "--height", "40", "--iters", "2", "--drain-timeout", "2000",
    ]);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("shed 0"), "{stdout}");
    assert!(stdout.contains("deadlined 0"), "{stdout}");
}
