//! Distributed execution's headline guarantee: a sharded run is
//! **bit-identical** to a solo run of the same configuration — same
//! labels, same centroid bits, same counts, same inertia bits — across
//! shard counts, kernels, the paper's three block shapes, and both
//! strip-store backings. The argument (leader-side deterministic
//! block-ordered reduction over pure per-block functions of the
//! shipped centroids) lives in EXPERIMENTS.md §Distributed; this file
//! is the proof matrix, plus the failure half of the contract: a shard
//! killed mid-round has its blocks re-queued onto survivors and the
//! recovered run is still bit-identical.

use std::sync::Arc;

use blockms::blocks::BlockShape;
use blockms::coordinator::{
    ClusterConfig, ClusterMode, ClusterOutput, Coordinator, CoordinatorConfig, IoMode,
    RunMachine, WorkerPool, SOLO_JOB,
};
use blockms::image::SyntheticOrtho;
use blockms::kmeans::kernel::KernelChoice;
use blockms::plan::ExecPlan;
use blockms::shard::{spawn_loopback_shard, ShardEndpoints, ShardSpec};

fn counts_of(labels: &[u32], k: usize) -> Vec<u64> {
    let mut counts = vec![0u64; k];
    for &l in labels {
        counts[l as usize] += 1;
    }
    counts
}

/// Exact-bits comparison: labels and counts by value, centroids and
/// inertia by raw bit pattern (`==` on floats would also pass for
/// -0.0 vs 0.0, which the wire must not conflate).
fn assert_bit_identical(tag: &str, got: &ClusterOutput, want: &ClusterOutput, k: usize) {
    assert_eq!(got.labels, want.labels, "{tag}: labels diverged");
    assert_eq!(got.iterations, want.iterations, "{tag}: iteration count diverged");
    assert_eq!(got.centroids.len(), want.centroids.len(), "{tag}: centroid count diverged");
    for (i, (a, b)) in got.centroids.iter().zip(want.centroids.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: centroid component {i} diverged");
    }
    assert_eq!(got.inertia.to_bits(), want.inertia.to_bits(), "{tag}: inertia bits diverged");
    assert_eq!(
        counts_of(&got.labels, k),
        counts_of(&want.labels, k),
        "{tag}: cluster counts diverged"
    );
}

/// The matrix: {2, 4} shards × every kernel the shards can host × the
/// paper's three block shapes × memory- and file-backed strip stores,
/// each against a fresh solo twin of the identical configuration.
#[test]
fn sharded_matrix_is_bit_identical_to_solo() {
    let img = Arc::new(SyntheticOrtho::default().with_seed(42).generate(40, 36));
    let ccfg = ClusterConfig { k: 3, max_iters: 6, ..Default::default() };
    let shapes = [
        BlockShape::Rows { band_rows: 7 },
        BlockShape::Cols { band_cols: 9 },
        BlockShape::Square { side: 11 },
    ];
    let kernels = [
        KernelChoice::Naive,
        KernelChoice::Pruned,
        KernelChoice::Lanes,
        KernelChoice::Simd,
    ];
    for shape in shapes {
        for kernel in kernels {
            for file_backed in [false, true] {
                let cfg = CoordinatorConfig {
                    exec: ExecPlan::pinned(shape).with_workers(2).with_kernel(kernel),
                    io: IoMode::Strips { strip_rows: 8, file_backed },
                    ..Default::default()
                };
                let solo = Coordinator::new(cfg.clone()).cluster(&img, &ccfg).unwrap();
                for shards in [2usize, 4] {
                    let out = Coordinator::new(cfg.clone())
                        .with_shards(ShardEndpoints::Loopback { shards })
                        .cluster(&img, &ccfg)
                        .unwrap();
                    let tag = format!(
                        "{shards} shards, {kernel:?}, {shape:?}, file_backed={file_backed}"
                    );
                    assert_bit_identical(&tag, &out, &solo, ccfg.k);
                }
            }
        }
    }
}

/// Direct (non-strip) block sourcing shards identically too — the spec
/// ships `strip_rows = 0` and shards crop from the rebuilt raster.
#[test]
fn sharded_direct_io_is_bit_identical_to_solo() {
    let img = Arc::new(SyntheticOrtho::default().with_seed(17).generate(33, 29));
    let ccfg = ClusterConfig { k: 4, max_iters: 5, ..Default::default() };
    let cfg = CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 9 })
            .with_workers(2)
            .with_kernel(KernelChoice::Pruned),
        ..Default::default()
    };
    let solo = Coordinator::new(cfg.clone()).cluster(&img, &ccfg).unwrap();
    let out = Coordinator::new(cfg)
        .with_shards(ShardEndpoints::Loopback { shards: 3 })
        .cluster(&img, &ccfg)
        .unwrap();
    assert_bit_identical("3 shards, direct I/O", &out, &solo, ccfg.k);
}

/// Kill one of two shards mid-round: its in-flight block fails with a
/// transport error, the proxy dies, and the retry budget re-queues the
/// block onto the surviving shard — the run completes and stays
/// bit-identical to solo. This drives the round protocol by hand with
/// [`spawn_loopback_shard`]'s kill switch (the coordinator's sharded
/// pool never arms one).
#[test]
fn killed_shard_mid_round_recovers_bit_identically() {
    let img = Arc::new(SyntheticOrtho::default().with_seed(7).generate(40, 32));
    let ccfg = ClusterConfig { k: 3, max_iters: 5, fixed_iters: Some(4), ..Default::default() };
    let exec = ExecPlan::pinned(BlockShape::Square { side: 8 })
        .with_workers(1)
        .with_kernel(KernelChoice::Lanes);
    let solo = Coordinator::new(CoordinatorConfig { exec, ..Default::default() })
        .cluster(&img, &ccfg)
        .unwrap();

    // One connection per shard; shard A's whole process "dies" after
    // serving 3 blocks (round 1 has 20, so it dies mid-round with a
    // block in flight on its connection).
    let (ends_a, guard_a) = spawn_loopback_shard(1, Some(3));
    let (ends_b, guard_b) = spawn_loopback_shard(1, None);
    let transports: Vec<_> = ends_a.into_iter().chain(ends_b).collect();
    let pool = WorkerPool::spawn_sharded(transports);
    let spec = ShardSpec::from_run(&img, &ccfg, ClusterMode::Global, &IoMode::Direct, &exec);
    pool.register_shard_spec(SOLO_JOB, Arc::new(spec));
    pool.warmup(SOLO_JOB).unwrap();

    let plan = Arc::new(exec.block_plan(img.height(), img.width()));
    let init = ccfg.init.centroids(img.as_pixels(), ccfg.k, img.channels(), ccfg.seed);
    let mut machine =
        RunMachine::new(ClusterMode::Global, plan, img.channels(), &ccfg, init, None);
    while !machine.done() {
        let jobs = machine.start_round(SOLO_JOB);
        for outcome in pool.run_round_resilient(jobs, 2).unwrap() {
            if machine.wants(&outcome) {
                machine.absorb(outcome).unwrap();
            }
        }
        machine.finish_round().unwrap();
    }
    pool.shutdown();
    drop(guard_a);
    drop(guard_b);

    let m = machine.into_output().unwrap();
    let labels = m.labels.into_dense().unwrap();
    assert_eq!(labels, solo.labels, "recovered labels diverged from solo");
    assert_eq!(m.iterations, solo.iterations);
    assert_eq!(m.centroids.len(), solo.centroids.len());
    for (i, (a, b)) in m.centroids.iter().zip(solo.centroids.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "recovered centroid component {i} diverged");
    }
    assert_eq!(m.inertia.to_bits(), solo.inertia.to_bits(), "recovered inertia bits diverged");
    assert_eq!(counts_of(&labels, ccfg.k), counts_of(&solo.labels, ccfg.k));
}
