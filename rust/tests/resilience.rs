//! Fault-tolerance integration tests: kill/resume bit-identity across
//! kernels × block shapes × I/O backings, single-block retry isolation,
//! injected-panic recovery, and checkpoint-file rejection.
//!
//! The acceptance bar everywhere is *bitwise* equality with an
//! uninterrupted fault-free run: retries and resume may cost time but
//! must never change a label, a centroid byte, or the inertia bits.

use std::path::PathBuf;
use std::sync::Arc;

use blockms::blocks::BlockShape;
use blockms::coordinator::{
    ClusterConfig, ClusterOutput, Coordinator, CoordinatorConfig, IoMode, Schedule,
};
use blockms::image::{Raster, SyntheticOrtho};
use blockms::kmeans::kernel::KernelChoice;
use blockms::plan::ExecPlan;
use blockms::resilience::{FaultKind, FaultPlan};
use blockms::service::{ClusterServer, JobSpec, ServerConfig};

fn scene(h: usize, w: usize, seed: u64) -> Arc<Raster> {
    Arc::new(SyntheticOrtho::default().with_seed(seed).generate(h, w))
}

/// Per-test unique checkpoint path (tests in this binary run in
/// parallel; the pid guards against stale files from other runs).
fn ckpt_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("blockms_test_p{}_{tag}.ckpt", std::process::id()))
}

fn assert_bitwise_eq(got: &ClusterOutput, want: &ClusterOutput, ctx: &str) {
    assert_eq!(got.labels, want.labels, "{ctx}: labels diverged");
    assert_eq!(got.centroids, want.centroids, "{ctx}: centroids diverged");
    assert_eq!(
        got.inertia.to_bits(),
        want.inertia.to_bits(),
        "{ctx}: inertia diverged"
    );
    assert_eq!(got.iterations, want.iterations, "{ctx}: iteration count diverged");
}

/// The tentpole acceptance matrix: checkpoint mid-run at several rounds,
/// across kernels (naive/pruned/lanes — pruned carries cross-round
/// worker state that a resume must rebuild), block shapes, and
/// memory/file strip backings; every resumed run must equal the
/// uninterrupted reference bitwise.
#[test]
fn kill_resume_matrix_is_bit_identical() {
    let img = scene(48, 40, 11);
    let ccfg = ClusterConfig {
        k: 3,
        fixed_iters: Some(6),
        seed: 5,
        ..Default::default()
    };
    let cells: &[(KernelChoice, BlockShape, IoMode)] = &[
        (KernelChoice::Naive, BlockShape::Square { side: 13 }, IoMode::Direct),
        (
            KernelChoice::Pruned,
            BlockShape::Cols { band_cols: 13 },
            IoMode::Strips {
                strip_rows: 9,
                file_backed: false,
            },
        ),
        (
            KernelChoice::Lanes,
            BlockShape::Rows { band_rows: 11 },
            IoMode::Strips {
                strip_rows: 7,
                file_backed: true,
            },
        ),
    ];
    for (i, (kernel, shape, io)) in cells.iter().enumerate() {
        let exec = ExecPlan::pinned(*shape).with_workers(3).with_kernel(*kernel);
        let reference = Coordinator::new(CoordinatorConfig {
            exec,
            io: io.clone(),
            ..Default::default()
        })
        .cluster(&img, &ccfg)
        .unwrap();
        // Kill early (one checkpoint behind) and late (several rounds
        // of progress on disk) — `.after(r)` lets r visits to the block
        // succeed, so the run dies in round r+1.
        for kill_after in [2usize, 5] {
            let ctx = format!("{kernel:?}/{shape:?}/kill after round {kill_after}");
            let path = ckpt_path(&format!("matrix_{i}_{kill_after}"));
            let _ = std::fs::remove_file(&path);
            let died = Coordinator::new(CoordinatorConfig {
                exec: exec.with_checkpoint_every(2),
                io: io.clone(),
                fault: Some(FaultPlan::always(1, FaultKind::Error).after(kill_after)),
                checkpoint: Some(path.clone()),
                ..Default::default()
            })
            .cluster(&img, &ccfg);
            assert!(died.is_err(), "{ctx}: the unhealing fault must kill the run");
            let resumed = Coordinator::new(CoordinatorConfig {
                exec,
                io: io.clone(),
                resume: Some(path.clone()),
                ..Default::default()
            })
            .cluster(&img, &ccfg)
            .unwrap_or_else(|e| panic!("{ctx}: resume failed: {e:#}"));
            assert_bitwise_eq(&resumed, &reference, &ctx);
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// A transient single-block failure under a retry budget: only the
/// failed block is recomputed, and the result is bitwise equal to a
/// fault-free twin. Covers both the compute-error and the reader-I/O
/// fault kinds.
#[test]
fn single_block_retry_is_isolated_and_bit_identical() {
    let img = scene(44, 52, 7);
    let ccfg = ClusterConfig {
        k: 4,
        fixed_iters: Some(4),
        seed: 3,
        ..Default::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Square { side: 14 }).with_workers(3);
    let clean = Coordinator::new(CoordinatorConfig {
        exec,
        io: IoMode::Strips {
            strip_rows: 8,
            file_backed: false,
        },
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();
    for kind in [FaultKind::Error, FaultKind::ReaderIo] {
        let fault = FaultPlan::new(2, kind, 1);
        let out = Coordinator::new(CoordinatorConfig {
            exec: exec.with_retries(1),
            io: IoMode::Strips {
                strip_rows: 8,
                file_backed: false,
            },
            fault: Some(fault.clone()),
            ..Default::default()
        })
        .cluster(&img, &ccfg)
        .unwrap_or_else(|e| panic!("{kind:?}: retry budget 1 must absorb one failure: {e:#}"));
        assert!(fault.trips() >= 1, "{kind:?}: the fault never fired");
        assert_bitwise_eq(&out, &clean, &format!("{kind:?} retried"));
    }
}

/// A worker panic mid-block is caught, reported as a job failure, and —
/// under a retry budget — absorbed without value drift. The pool's
/// supervisor respawns the worker loop, so later rounds still have full
/// capacity.
#[test]
fn injected_panic_recovers_bit_identically() {
    let img = scene(40, 40, 19);
    let ccfg = ClusterConfig {
        k: 3,
        fixed_iters: Some(3),
        seed: 9,
        ..Default::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Rows { band_rows: 10 }).with_workers(2);
    let clean = Coordinator::new(CoordinatorConfig {
        exec,
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();

    // Without retries, the panic surfaces with its actual message.
    let err = Coordinator::new(CoordinatorConfig {
        exec,
        fault: Some(FaultPlan::new(1, FaultKind::Panic, 1)),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("panicked") && msg.contains("injected panic"),
        "panic message must survive the supervisor: {msg}"
    );

    // With a budget, the same panic is absorbed bit-identically.
    let out = Coordinator::new(CoordinatorConfig {
        exec: exec.with_retries(1),
        fault: Some(FaultPlan::new(1, FaultKind::Panic, 1)),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();
    assert_bitwise_eq(&out, &clean, "panic retried");
}

/// Zero retries = the seed behaviour: an injected failure fails the run
/// loudly, naming the block. An exhausted budget names the attempt
/// count and the budget.
#[test]
fn zero_retry_and_exhausted_budget_fail_loudly() {
    let img = scene(36, 36, 23);
    let ccfg = ClusterConfig {
        k: 2,
        fixed_iters: Some(2),
        seed: 1,
        ..Default::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Square { side: 12 }).with_workers(2);
    let err = Coordinator::new(CoordinatorConfig {
        exec,
        fault: Some(FaultPlan::always(1, FaultKind::Error)),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("block 1") && msg.contains("injected failure"),
        "fail-fast error must name the block and cause: {msg}"
    );

    let err = Coordinator::new(CoordinatorConfig {
        exec: exec.with_retries(2),
        fault: Some(FaultPlan::always(1, FaultKind::Error)),
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("retry budget 2"),
        "exhaustion must name the budget: {msg}"
    );
}

/// Checkpoint files are rejected when damaged or when they belong to a
/// different run configuration — never silently resumed into garbage.
#[test]
fn damaged_or_mismatched_checkpoints_are_rejected() {
    let img = scene(40, 32, 31);
    let ccfg = ClusterConfig {
        k: 3,
        fixed_iters: Some(4),
        seed: 2,
        ..Default::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Cols { band_cols: 11 }).with_workers(2);
    let path = ckpt_path("reject");
    let _ = std::fs::remove_file(&path);
    // Produce a genuine checkpoint by killing a run after round 2.
    let died = Coordinator::new(CoordinatorConfig {
        exec: exec.with_checkpoint_every(1),
        fault: Some(FaultPlan::always(0, FaultKind::Error).after(2)),
        checkpoint: Some(path.clone()),
        ..Default::default()
    })
    .cluster(&img, &ccfg);
    assert!(died.is_err());
    let good = std::fs::read(&path).unwrap();

    let resume_with = |bytes: &[u8], ccfg: &ClusterConfig| {
        std::fs::write(&path, bytes).unwrap();
        Coordinator::new(CoordinatorConfig {
            exec,
            resume: Some(path.clone()),
            ..Default::default()
        })
        .cluster(&img, ccfg)
    };

    // Truncated mid-header.
    let msg = format!("{:#}", resume_with(&good[..10], &ccfg).unwrap_err());
    assert!(msg.contains("truncated"), "{msg}");

    // Body corruption lands on the checksum.
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    let msg = format!("{:#}", resume_with(&flipped, &ccfg).unwrap_err());
    assert!(msg.contains("checksum mismatch"), "{msg}");

    // Not a checkpoint at all.
    let msg = format!("{:#}", resume_with(b"XXXXXXXX not a checkpoint", &ccfg).unwrap_err());
    assert!(msg.contains("bad magic"), "{msg}");

    // A pristine file from a *different* run configuration (k=4) is
    // caught by the fingerprint before any state is restored.
    let other = ClusterConfig { k: 4, ..ccfg.clone() };
    let msg = format!("{:#}", resume_with(&good, &other).unwrap_err());
    assert!(msg.contains("different run configuration"), "{msg}");

    let _ = std::fs::remove_file(&path);
}

/// The service path accepts the same checkpoints: a killed solo run's
/// file resumes as a server job, bit-identical to the uninterrupted
/// reference.
#[test]
fn service_job_resumes_a_killed_run_bit_identically() {
    let img = scene(44, 36, 41);
    let ccfg = ClusterConfig {
        k: 3,
        fixed_iters: Some(5),
        seed: 6,
        ..Default::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Square { side: 12 }).with_workers(2);
    let reference = Coordinator::new(CoordinatorConfig {
        exec,
        ..Default::default()
    })
    .cluster(&img, &ccfg)
    .unwrap();

    let path = ckpt_path("service_resume");
    let _ = std::fs::remove_file(&path);
    let died = Coordinator::new(CoordinatorConfig {
        exec: exec.with_checkpoint_every(2),
        fault: Some(FaultPlan::always(1, FaultKind::Error).after(4)),
        checkpoint: Some(path.clone()),
        ..Default::default()
    })
    .cluster(&img, &ccfg);
    assert!(died.is_err());

    let server = ClusterServer::start(ServerConfig {
        workers: 2,
        schedule: Schedule::Static,
        max_in_flight: 2,
    });
    let spec = JobSpec::new(Arc::clone(&img), exec, ccfg.clone()).with_resume(path.clone());
    let out = server.submit(spec).unwrap().wait_output().unwrap();
    assert_bitwise_eq(&out, &reference, "service resume");
    server.shutdown();
    let _ = std::fs::remove_file(&path);
}
