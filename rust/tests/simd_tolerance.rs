//! Tolerance-gated equivalence harness for the opt-in `--fma` SIMD mode.
//!
//! Fused multiply-add contracts `t * t + acc` into one rounding, so an
//! FMA run is deliberately **not** bit-identical to the lanes/naive
//! reference — that exactness contract belongs to the non-FMA modes and
//! is enforced in `tests/kernel_equivalence.rs`. What FMA must satisfy
//! instead (EXPERIMENTS.md §SIMD, the contract the ROADMAP requires for
//! any future non-bit-exact backend):
//!
//! - **centroids** within a small ULP band of the reference, per
//!   component;
//! - **inertia** within a small relative band;
//! - **labels** exactly equal *except* pixels whose two nearest centres
//!   are within the FMA rounding band — the only pixels whose argmin may
//!   legitimately flip — and every flip must land on a centre whose
//!   distance ties the reference winner within that band.

use blockms::kmeans::kernel::KernelChoice;
use blockms::kmeans::{KMeansConfig, SeqKMeans, SimdLevel, SimdMode};
use blockms::util::prng::Rng;

/// Max units-in-last-place between two f32s (∞ for sign disagreement on
/// non-zero values).
fn ulp_diff(a: f32, b: f32) -> u32 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() || (a.signum() != b.signum() && a != 0.0 && b != 0.0) {
        return u32::MAX;
    }
    let (ia, ib) = (a.abs().to_bits(), b.abs().to_bits());
    ia.abs_diff(ib)
}

/// Per-component centroid ULP bound. Each component is a `sum / count`
/// of ≤ ~1k addends whose per-pixel rounding differs by at most one ULP
/// under contraction; the quotient stays within a few ULPs.
const CENTROID_ULPS: u32 = 16;
/// Relative inertia bound: one contraction per pixel-distance, summed
/// in f64 — relative error stays far below this.
const INERTIA_REL: f64 = 1e-5;
/// Distance slack for legitimate label flips: the two candidate centres
/// must tie within this relative band for FMA to be allowed to disagree.
const TIE_REL: f32 = 1e-5;

fn pixels(n: usize, channels: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * channels).map(|_| rng.next_f32() * 255.0).collect()
}

/// Squared distance of pixel `p` to centroid `c`, in the reference
/// (non-fused) op order.
fn dist2(px: &[f32], channels: usize, p: usize, cen: &[f32], c: usize) -> f32 {
    let mut acc = 0.0f32;
    for ch in 0..channels {
        let t = px[p * channels + ch] - cen[c * channels + ch];
        acc += t * t;
    }
    acc
}

/// Assert the FMA tolerance contract between a reference result and an
/// FMA result of the same drive.
fn assert_within_tolerance(
    px: &[f32],
    channels: usize,
    k: usize,
    reference: &blockms::kmeans::KMeansResult,
    fma: &blockms::kmeans::KMeansResult,
) {
    assert_eq!(reference.iterations, fma.iterations, "iteration drift");
    for (i, (&a, &b)) in reference.centroids.iter().zip(&fma.centroids).enumerate() {
        assert!(
            ulp_diff(a, b) <= CENTROID_ULPS,
            "centroid component {i}: {a} vs {b} ({} ulps)",
            ulp_diff(a, b)
        );
    }
    let rel = (reference.inertia - fma.inertia).abs() / reference.inertia.max(1e-30);
    assert!(
        rel <= INERTIA_REL,
        "inertia {} vs {} (rel {rel})",
        reference.inertia,
        fma.inertia
    );
    let mut flips = 0usize;
    for (p, (&la, &lb)) in reference.labels.iter().zip(&fma.labels).enumerate() {
        if la == lb {
            continue;
        }
        flips += 1;
        // A flip is only legitimate on a near-exact distance tie —
        // measured against the *reference* centroids so the bound does
        // not launder real divergence through drifted centres.
        let da = dist2(px, channels, p, &reference.centroids, la as usize);
        let db = dist2(px, channels, p, &reference.centroids, lb as usize);
        let scale = da.max(db).max(f32::MIN_POSITIVE);
        assert!(
            (da - db).abs() <= TIE_REL * scale,
            "pixel {p}: flipped {la}->{lb} without a tie ({da} vs {db}, k={k})"
        );
    }
    // Ties are rare on continuous random data: a blowup here means the
    // FMA path diverged, not that it rounded differently.
    assert!(
        flips * 100 <= reference.labels.len(),
        "{flips}/{} labels flipped — more than the 1% tie budget",
        reference.labels.len()
    );
}

#[test]
fn fma_mode_stays_within_the_tolerance_contract() {
    let level = SimdLevel::detect();
    for &(n, channels) in &[(700usize, 3usize), (257, 1), (513, 4), (301, 5)] {
        for &k in &[2usize, 4, 8] {
            let px = pixels(n, channels, (n * k) as u64 + 0xF0A);
            let cfg = KMeansConfig {
                k,
                seed: 0x5EED ^ (k as u64),
                ..Default::default()
            };
            let reference =
                SeqKMeans::run_fixed_iters_with(&px, channels, &cfg, 6, KernelChoice::Lanes);
            let fma = SeqKMeans::run_fixed_iters_with_simd(
                &px,
                channels,
                &cfg,
                6,
                KernelChoice::Simd,
                SimdMode { level, fma: true },
            );
            assert_within_tolerance(&px, channels, k, &reference, &fma);
        }
    }
}

/// The portable FMA path (what non-x86 hosts without NEON run, and what
/// `BLOCKMS_SIMD=off --fma` clamps to) obeys the same contract.
#[test]
fn portable_fma_obeys_the_same_contract() {
    let px = pixels(640, 3, 0xDEC0DE);
    let cfg = KMeansConfig {
        k: 4,
        seed: 0xBEEF,
        ..Default::default()
    };
    let reference = SeqKMeans::run_fixed_iters_with(&px, 3, &cfg, 5, KernelChoice::Lanes);
    let fma = SeqKMeans::run_fixed_iters_with_simd(
        &px,
        3,
        &cfg,
        5,
        KernelChoice::Simd,
        SimdMode {
            level: SimdLevel::Portable,
            fma: true,
        },
    );
    assert_within_tolerance(&px, 3, 4, &reference, &fma);
}

/// Sanity anchor for the harness itself: a *non*-FMA simd run measured
/// with the same machinery reports zero ULP difference everywhere — the
/// tolerance harness agrees with the bit-identity tests where they
/// overlap.
#[test]
fn non_fma_measures_as_exactly_zero_distance() {
    let px = pixels(512, 3, 0xA11CE);
    let cfg = KMeansConfig {
        k: 4,
        seed: 0x7E57,
        ..Default::default()
    };
    let reference = SeqKMeans::run_fixed_iters_with(&px, 3, &cfg, 5, KernelChoice::Lanes);
    let simd = SeqKMeans::run_fixed_iters_with_simd(
        &px,
        3,
        &cfg,
        5,
        KernelChoice::Simd,
        SimdMode {
            level: SimdLevel::detect(),
            fma: false,
        },
    );
    assert_eq!(reference.labels, simd.labels);
    assert_eq!(reference.centroids, simd.centroids);
    assert!(reference.inertia.to_bits() == simd.inertia.to_bits());
    for (&a, &b) in reference.centroids.iter().zip(&simd.centroids) {
        assert_eq!(ulp_diff(a, b), 0);
    }
}
