//! Concurrency suite for the multi-job clustering service.
//!
//! The contract under test: N jobs interleaved over ONE shared worker
//! pool each produce output **bit-identical** to a solo
//! `Coordinator::cluster` of the same spec — across k, channel counts,
//! block shapes, and kernels — while cancellation, failure, and the
//! admission cap stay per-job properties that never leak across jobs.

use std::sync::Arc;

use blockms::blocks::BlockShape;
use blockms::coordinator::{
    ClusterConfig, ClusterMode, ClusterOutput, Coordinator, CoordinatorConfig, Engine, IoMode,
    Schedule,
};
use blockms::image::{Raster, SyntheticOrtho};
use blockms::kmeans::kernel::KernelChoice;
use blockms::plan::ExecPlan;
use blockms::resilience::{FaultKind, FaultPlan};
use blockms::service::{ClusterServer, JobSpec, JobStatus, ServerConfig};

fn image(channels: usize, h: usize, w: usize, seed: u64) -> Arc<Raster> {
    Arc::new(
        SyntheticOrtho::default()
            .with_channels(channels)
            .with_seed(seed)
            .generate(h, w),
    )
}

/// The paper's three block approaches, scaled to the test image.
fn paper_shapes() -> [BlockShape; 3] {
    [
        BlockShape::Rows { band_rows: 10 },
        BlockShape::Cols { band_cols: 7 },
        BlockShape::Square { side: 13 },
    ]
}

fn solo(spec: &JobSpec, workers: usize) -> ClusterOutput {
    // The SAME embedded ExecPlan drives the solo run — the service and
    // solo paths cannot disagree on kernel/layout/shape by construction.
    Coordinator::new(CoordinatorConfig {
        exec: spec.exec.with_workers(workers),
        engine: Engine::Native,
        mode: spec.mode,
        io: IoMode::Direct, // I/O path must not change values
        schedule: Schedule::Dynamic,
        ..Default::default()
    })
    .cluster(spec.raster().expect("test jobs carry rasters"), &spec.cluster)
    .expect("solo run")
}

fn cluster_counts(labels: &[u32], k: usize) -> Vec<u64> {
    let mut counts = vec![0u64; k];
    for &l in labels {
        counts[l as usize] += 1;
    }
    counts
}

/// Labels, centroids, per-cluster counts, and inertia all bit-identical.
fn assert_identical(tag: &str, got: &ClusterOutput, want: &ClusterOutput, k: usize) {
    assert_eq!(got.labels, want.labels, "{tag}: labels differ");
    assert_eq!(got.centroids, want.centroids, "{tag}: centroids differ");
    assert_eq!(
        cluster_counts(&got.labels, k),
        cluster_counts(&want.labels, k),
        "{tag}: counts differ"
    );
    assert_eq!(
        got.inertia.to_bits(),
        want.inertia.to_bits(),
        "{tag}: inertia differs ({} vs {})",
        got.inertia,
        want.inertia
    );
    assert_eq!(got.iterations, want.iterations, "{tag}: iterations differ");
    assert_eq!(got.converged, want.converged, "{tag}: convergence differs");
}

/// The acceptance matrix: k∈{2,4,8} × C∈{1,3,4} × all three paper block
/// shapes, with kernels naive|pruned|fused|lanes cycling through the cells.
/// All 27 jobs run concurrently through one 4-worker pool and each must
/// equal its solo run exactly.
#[test]
fn mixed_jobs_bit_identical_to_solo() {
    let (h, w) = (40, 35);
    let mut specs = Vec::new();
    let mut idx = 0u64;
    for &k in &[2usize, 4, 8] {
        for &channels in &[1usize, 3, 4] {
            for shape in paper_shapes() {
                let kernel = KernelChoice::ALL[(idx as usize) % KernelChoice::ALL.len()];
                let img = image(channels, h, w, 100 + idx);
                specs.push(
                    JobSpec::new(
                        img,
                        ExecPlan::pinned(shape).with_kernel(kernel),
                        ClusterConfig {
                            k,
                            seed: 900 + idx,
                            ..Default::default()
                        },
                    ),
                );
                idx += 1;
            }
        }
    }
    assert_eq!(specs.len(), 27);

    let server = ClusterServer::start(ServerConfig {
        workers: 4,
        schedule: Schedule::Dynamic,
        max_in_flight: 8,
        ..Default::default()
    });
    // Submission from one thread: the admission gate (cap 8) provides
    // the backpressure while earlier jobs are still in flight.
    let handles: Vec<_> = specs
        .iter()
        .map(|s| server.submit(s.clone()).expect("submit"))
        .collect();
    for (i, (spec, handle)) in specs.iter().zip(&handles).enumerate() {
        let got = handle.wait_output().expect("service job");
        let want = solo(spec, 3);
        let tag = format!(
            "job {i} (k={}, kernel={}, blocks={})",
            spec.cluster.k,
            spec.exec.kernel,
            spec.block_plan().len()
        );
        assert_identical(&tag, &got, &want, spec.cluster.k);
        // service jobs never pay pool spawn cost
        assert_eq!(got.spawn_secs, 0.0);
    }
    let stats = server.stats();
    assert_eq!(stats.completed, 27);
    assert_eq!(stats.failed, 0);
    assert!(
        stats.max_open_jobs <= 8,
        "admission cap exceeded: {} jobs open",
        stats.max_open_jobs
    );
    server.shutdown();
}

/// Static scheduling and local mode also hold the solo-equivalence
/// contract through the shared pool.
#[test]
fn static_schedule_and_local_mode_match_solo() {
    let (h, w) = (48, 44);
    let server = ClusterServer::start(ServerConfig {
        workers: 3,
        schedule: Schedule::Static,
        max_in_flight: 4,
        ..Default::default()
    });
    let mut pairs = Vec::new();
    for (i, mode) in [ClusterMode::Global, ClusterMode::Local, ClusterMode::Global]
        .into_iter()
        .enumerate()
    {
        let img = image(3, h, w, 40 + i as u64);
        let spec = JobSpec::new(
            img,
            ExecPlan::pinned(BlockShape::Square { side: 16 }).with_kernel(KernelChoice::Pruned),
            ClusterConfig {
                k: 3,
                seed: 70 + i as u64,
                ..Default::default()
            },
        )
        .with_mode(mode);
        let handle = server.submit(spec.clone()).unwrap();
        pairs.push((spec, handle));
    }
    for (i, (spec, handle)) in pairs.iter().enumerate() {
        let got = handle.wait_output().unwrap();
        let want = solo(spec, 2);
        assert_identical(&format!("static job {i} ({:?})", spec.mode), &got, &want, 3);
    }
    server.shutdown();
}

/// Strip-store I/O jobs: per-job file-backed stores, counted accesses,
/// and values identical to direct reads.
#[test]
fn strip_io_jobs_are_isolated_and_exact() {
    let (h, w) = (40, 30);
    let server = ClusterServer::start(ServerConfig {
        workers: 2,
        schedule: Schedule::Dynamic,
        max_in_flight: 4,
        ..Default::default()
    });
    // Two same-shaped jobs at once: with per-job backing files a name
    // collision would corrupt one of them.
    let mut pairs = Vec::new();
    for i in 0..2u64 {
        let img = image(3, h, w, 60 + i);
        let spec = JobSpec::new(
            img,
            ExecPlan::pinned(BlockShape::Square { side: 12 }),
            ClusterConfig {
                k: 2,
                seed: 80 + i,
                fixed_iters: Some(3),
                ..Default::default()
            },
        )
        .with_io(IoMode::Strips {
            strip_rows: 8,
            file_backed: true,
        });
        let handle = server.submit(spec.clone()).unwrap();
        pairs.push((spec, handle));
    }
    for (spec, handle) in &pairs {
        let got = handle.wait_output().unwrap();
        let want = solo(spec, 2); // solo reads direct: values must agree
        assert_identical("strip job", &got, &want, 2);
        let io = got.io_stats.expect("strip jobs report io stats");
        // 3 step rounds + 1 assign = 4 passes over all blocks
        let plan = spec.block_plan();
        let (per_pass, _, _) = blockms::stripstore::read_amplification(&plan, 8);
        assert_eq!(io.strip_reads as usize, per_pass * 4);
        assert_eq!(io.block_reads as usize, plan.len() * 4);
    }
    server.shutdown();
}

/// A lanes-kernel strip-I/O job through the service: the per-worker SoA
/// tile arena drops strip reads to once per block per job (static
/// schedule keeps block ownership stable), and the output stays
/// bit-identical to the solo run of the same spec.
#[test]
fn lanes_service_job_fills_tiles_once_and_matches_solo() {
    let (h, w) = (48, 40);
    let server = ClusterServer::start(ServerConfig {
        workers: 2,
        schedule: Schedule::Static,
        max_in_flight: 2,
        ..Default::default()
    });
    let img = image(3, h, w, 91);
    let spec = JobSpec::new(
        img,
        ExecPlan::pinned(BlockShape::Square { side: 14 }).with_kernel(KernelChoice::Lanes),
        ClusterConfig {
            k: 4,
            seed: 92,
            fixed_iters: Some(3),
            ..Default::default()
        },
    )
    .with_io(IoMode::Strips {
        strip_rows: 8,
        file_backed: false,
    });
    let got = server.submit(spec.clone()).unwrap().wait_output().unwrap();
    let want = solo(&spec, 2);
    assert_identical("lanes strip job", &got, &want, 4);
    let io = got.io_stats.expect("strip jobs report io stats");
    // 4 passes run, but every block's tile is filled exactly once.
    let plan = spec.block_plan();
    let (per_pass, _, _) = blockms::stripstore::read_amplification(&plan, 8);
    assert_eq!(io.strip_reads as usize, per_pass);
    assert_eq!(io.block_reads as usize, plan.len());
    server.shutdown();
}

/// Cancelling one job mid-run leaves every other job's result untouched
/// (still bit-identical to solo).
#[test]
fn cancellation_mid_round_leaves_others_untouched() {
    let (h, w) = (96, 90);
    let server = ClusterServer::start(ServerConfig {
        workers: 3,
        schedule: Schedule::Dynamic,
        max_in_flight: 4,
        ..Default::default()
    });
    let mut specs = Vec::new();
    for i in 0..3u64 {
        let img = image(3, h, w, 20 + i);
        specs.push(JobSpec::new(
            img,
            ExecPlan::pinned(BlockShape::Square { side: 24 }),
            ClusterConfig {
                k: 6,
                seed: 30 + i,
                fixed_iters: Some(40), // long enough to cancel mid-run
                ..Default::default()
            },
        ));
    }
    let handles: Vec<_> = specs
        .iter()
        .map(|s| server.submit(s.clone()).unwrap())
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(3));
    handles[1].cancel();
    let st1 = handles[1].wait();
    // The victim is cancelled (or, on a very fast machine, already done);
    // never failed.
    assert!(
        matches!(st1, JobStatus::Cancelled | JobStatus::Done(_)),
        "unexpected status: {}",
        st1.label()
    );
    for i in [0usize, 2] {
        let got = handles[i].wait_output().expect("survivor job");
        let want = solo(&specs[i], 2);
        assert_identical(&format!("survivor {i}"), &got, &want, 6);
    }
    let stats = server.stats();
    assert_eq!(stats.cancelled + stats.completed, 3);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.admission.in_flight, 0, "slots must be released");
    server.shutdown();
}

/// A worker-side failure in one job neither poisons the pool nor the
/// neighbours.
#[test]
fn failed_job_does_not_poison_the_pool() {
    let (h, w) = (36, 33);
    let server = ClusterServer::start(ServerConfig {
        workers: 2,
        schedule: Schedule::Dynamic,
        max_in_flight: 3,
        ..Default::default()
    });
    let mut failing = JobSpec::new(
        image(3, h, w, 1),
        ExecPlan::pinned(BlockShape::Square { side: 11 }),
        ClusterConfig {
            k: 2,
            seed: 2,
            ..Default::default()
        },
    );
    failing.fault = Some(FaultPlan::always(1, FaultKind::Error));
    let healthy: Vec<JobSpec> = (0..2u64)
        .map(|i| {
            JobSpec::new(
                image(3, h, w, 10 + i),
                ExecPlan::pinned(BlockShape::Rows { band_rows: 9 }).with_kernel(KernelChoice::Fused),
                ClusterConfig {
                    k: 4,
                    seed: 50 + i,
                    ..Default::default()
                },
            )
        })
        .collect();
    let h_fail = server.submit(failing).unwrap();
    let h_ok: Vec<_> = healthy
        .iter()
        .map(|s| server.submit(s.clone()).unwrap())
        .collect();
    match h_fail.wait() {
        JobStatus::Failed(msg) => {
            assert!(msg.contains("injected failure"), "{msg}");
        }
        other => panic!("expected failure, got {}", other.label()),
    }
    for (spec, handle) in healthy.iter().zip(&h_ok) {
        let got = handle.wait_output().expect("healthy job");
        assert_identical("healthy neighbour", &got, &solo(spec, 2), 4);
    }
    // The pool survives: a fresh job after the failure still works.
    let again = healthy[0].clone();
    let got = server.submit(again).unwrap().wait_output().unwrap();
    assert_identical("post-failure job", &got, &solo(&healthy[0], 2), 4);
    let stats = server.stats();
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.completed, 3);
    server.shutdown();
}

/// The admission cap is never exceeded, measured by pool instrumentation
/// (high water of simultaneously registered jobs), under 12 competing
/// submitter threads.
#[test]
fn admission_cap_never_exceeded() {
    let cap = 3;
    let server = Arc::new(ClusterServer::start(ServerConfig {
        workers: 2,
        schedule: Schedule::Dynamic,
        max_in_flight: cap,
        ..Default::default()
    }));
    let mut threads = Vec::new();
    for t in 0..12u64 {
        let server = Arc::clone(&server);
        threads.push(std::thread::spawn(move || {
            let (h, w) = (32, 30);
            let spec = JobSpec::new(
                image(3, h, w, 200 + t),
                ExecPlan::pinned(BlockShape::Square { side: 10 }),
                ClusterConfig {
                    k: 3,
                    seed: 300 + t,
                    fixed_iters: Some(4),
                    ..Default::default()
                },
            );
            // blocks at the gate when the cap is reached
            server.submit(spec).unwrap().wait_output().unwrap().labels.len()
        }));
    }
    for t in threads {
        assert_eq!(t.join().unwrap(), 32 * 30);
    }
    let stats = server.stats();
    assert_eq!(stats.completed, 12);
    assert!(
        stats.admission.high_water <= cap,
        "admission high water {} exceeds cap {cap}",
        stats.admission.high_water
    );
    assert!(
        stats.max_open_jobs <= cap,
        "pool saw {} jobs open at once (cap {cap})",
        stats.max_open_jobs
    );
    assert_eq!(stats.admission.in_flight, 0);
    Arc::try_unwrap(server).ok().expect("sole owner").shutdown();
}

/// `try_submit` sheds instead of blocking when the gate is full.
#[test]
fn try_submit_sheds_at_capacity() {
    let (h, w) = (128, 120);
    let server = ClusterServer::start(ServerConfig {
        workers: 1,
        schedule: Schedule::Dynamic,
        max_in_flight: 2,
        ..Default::default()
    });
    let heavy: Vec<_> = (0..2u64)
        .map(|i| {
            let spec = JobSpec::new(
                image(3, h, w, 400 + i),
                ExecPlan::pinned(BlockShape::Square { side: 32 }),
                ClusterConfig {
                    k: 8,
                    seed: 500 + i,
                    fixed_iters: Some(60),
                    ..Default::default()
                },
            );
            server.submit(spec).unwrap()
        })
        .collect();
    let small = JobSpec::new(
        image(3, 16, 16, 9),
        ExecPlan::pinned(BlockShape::Square { side: 8 }),
        ClusterConfig {
            k: 2,
            seed: 9,
            ..Default::default()
        },
    );
    assert!(
        server.try_submit(small).unwrap().is_none(),
        "gate should be full"
    );
    assert!(server.stats().admission.rejected >= 1);
    for h in heavy {
        h.cancel();
        h.wait();
    }
    server.shutdown();
}
