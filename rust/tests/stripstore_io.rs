//! Strip-store I/O contract tests.
//!
//! Three properties, on BOTH backings (memory and real file):
//!
//! 1. **Counted = closed form.** The `AccessStats` strip-read counter
//!    after one full pass over a plan equals the closed-form
//!    `read_amplification` prediction, for the paper's three block
//!    shapes (row / column / square, §4 Cases 1–3 scaled down 1:10).
//! 2. **Concurrent readers see consistent bytes.** Several
//!    `StripReader`s racing over the same store each reconstruct every
//!    block bit-identical to a direct crop of the source raster.
//! 3. **Cache hits/misses = closed form.** With the shared strip cache
//!    enabled, the hit/miss counters after a single-reader pass equal
//!    an exact LRU simulation of the plan's strip access sequence —
//!    row plans get no reuse, column plans hit on every revisit.

use std::sync::Arc;

use blockms::blocks::{BlockPlan, BlockShape};
use blockms::image::{Raster, SyntheticOrtho};
use blockms::stripstore::{read_amplification, Backing, StripStore};

/// The paper's 4656×5793 hero image scaled ~1:10 (width 466, height 579).
const H: usize = 579;
const W: usize = 466;
const STRIP_ROWS: usize = 8;

fn hero_image() -> Raster {
    SyntheticOrtho::default().with_seed(41).generate(H, W)
}

/// Row / square / column shapes at 1:10 of the paper's Cases 1–3.
fn paper_shapes() -> [(&'static str, BlockShape); 3] {
    [
        ("row", BlockShape::Custom { rows: 120, cols: W }),
        ("square", BlockShape::Square { side: 120 }),
        ("column", BlockShape::Custom { rows: H, cols: 100 }),
    ]
}

fn backings(tag: &str) -> [Backing; 2] {
    [
        Backing::Memory,
        Backing::File(std::env::temp_dir().join(format!("blockms_striptest_{tag}"))),
    ]
}

#[test]
fn counted_reads_equal_closed_form_on_both_backings() {
    let img = hero_image();
    for (name, shape) in paper_shapes() {
        let plan = BlockPlan::new(H, W, shape);
        let (expected_reads, total_strips, amp) = read_amplification(&plan, STRIP_ROWS);
        assert!(total_strips > 0 && amp >= 1.0);
        for backing in backings(name) {
            let file_backed = matches!(backing, Backing::File(_));
            let store = StripStore::new(&img, STRIP_ROWS, backing).unwrap();
            let mut reader = store.reader().unwrap();
            let mut buf = Vec::new();
            for region in plan.iter() {
                reader.read_block(region, &mut buf).unwrap();
            }
            let snap = store.stats().snapshot();
            assert_eq!(
                snap.strip_reads as usize, expected_reads,
                "{name} (file_backed={file_backed}): counted != closed form"
            );
            assert_eq!(snap.block_reads as usize, plan.len());
            assert!(snap.bytes_read > 0);
        }
    }
}

/// The column case is the paper's worst case: ⌈466/100⌉ = 5 column
/// blocks, each spanning every strip → the whole file is read exactly
/// 5×. The row case is the best case: amplification 1 (strip-aligned
/// bands).
#[test]
fn paper_case_amplifications_at_one_tenth_scale() {
    let col_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: H, cols: 100 });
    let (_, _, col_amp) = read_amplification(&col_plan, STRIP_ROWS);
    assert_eq!(col_amp, 5.0, "column blocks must read the file 5x");

    let row_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: 120, cols: W });
    let (_, _, row_amp) = read_amplification(&row_plan, STRIP_ROWS);
    assert!(row_amp < 1.01, "row blocks must approach amplification 1, got {row_amp}");

    let sq_plan = BlockPlan::new(H, W, BlockShape::Square { side: 120 });
    let (_, _, sq_amp) = read_amplification(&sq_plan, STRIP_ROWS);
    // 466/120 → 4 blocks per strip row: every strip read ~4x
    assert!((sq_amp - 4.0).abs() < 0.05, "square amplification {sq_amp}");
}

/// Exact LRU simulation of a single reader visiting `plan`'s blocks in
/// order (strips ascending within each block): the closed form the
/// counted hit/miss numbers must equal.
fn simulate_lru(plan: &BlockPlan, strip_rows: usize, cap: usize) -> (u64, u64) {
    let (mut hits, mut misses) = (0u64, 0u64);
    let mut tick = 0u64;
    let mut resident: Vec<(usize, u64)> = Vec::new(); // (strip, last_used)
    for b in plan.iter() {
        let first = b.row0 / strip_rows;
        let last = (b.row_end() - 1) / strip_rows;
        for s in first..=last {
            tick += 1;
            if let Some(e) = resident.iter_mut().find(|(st, _)| *st == s) {
                e.1 = tick;
                hits += 1;
            } else {
                misses += 1;
                resident.push((s, tick));
                if resident.len() > cap {
                    let lru = resident
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, (_, used))| *used)
                        .map(|(i, _)| i)
                        .unwrap();
                    resident.remove(lru);
                }
            }
        }
    }
    (hits, misses)
}

#[test]
fn cache_hit_miss_counts_equal_closed_form_on_both_backings() {
    let img = hero_image();
    let total_strips = H.div_ceil(STRIP_ROWS);
    // Full capacity and a deliberately thrashing capacity.
    for cap in [total_strips, 3] {
        for (name, shape) in paper_shapes() {
            let plan = BlockPlan::new(H, W, shape);
            let (want_hits, want_misses) = simulate_lru(&plan, STRIP_ROWS, cap);
            for backing in backings(&format!("cache_{name}_{cap}")) {
                let file_backed = matches!(backing, Backing::File(_));
                let mut store = StripStore::new(&img, STRIP_ROWS, backing).unwrap();
                store.enable_cache(cap);
                let mut reader = store.reader().unwrap();
                let mut buf = Vec::new();
                for region in plan.iter() {
                    reader.read_block(region, &mut buf).unwrap();
                    assert_eq!(buf, img.crop(region), "{name}: cached bytes differ");
                }
                let snap = store.stats().snapshot();
                assert_eq!(
                    (snap.strip_cache_hits, snap.strip_cache_misses),
                    (want_hits, want_misses),
                    "{name} cap={cap} (file_backed={file_backed})"
                );
                // Only misses transfer from the backing.
                assert_eq!(snap.strip_reads, want_misses, "{name} cap={cap}");
            }
        }
    }
}

/// The paper-shape headline numbers at full cache capacity: the column
/// plan's 5× re-read collapses to hits, the (strip-aligned) row plan
/// has nothing to reuse, and the square plan hits on 3 of its 4 visits
/// to each strip.
#[test]
fn column_plans_reuse_square_partially_rows_never() {
    let total_strips = H.div_ceil(STRIP_ROWS) as u64;

    let col_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: H, cols: 100 });
    let (hits, misses) = simulate_lru(&col_plan, STRIP_ROWS, total_strips as usize);
    assert_eq!(misses, total_strips);
    assert_eq!(hits, 4 * total_strips, "5 column blocks -> 4 revisits");

    let row_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: 120, cols: W });
    let (hits, _) = simulate_lru(&row_plan, STRIP_ROWS, total_strips as usize);
    assert_eq!(hits, 0, "strip-aligned rows have no reuse");

    let sq_plan = BlockPlan::new(H, W, BlockShape::Square { side: 120 });
    let (hits, misses) = simulate_lru(&sq_plan, STRIP_ROWS, total_strips as usize);
    assert_eq!(misses, total_strips);
    let (reads, _, _) = read_amplification(&sq_plan, STRIP_ROWS);
    assert_eq!(hits, reads as u64 - total_strips);

    // With a single-strip cache, the column plan's stride-through access
    // pattern defeats LRU entirely: every access misses.
    let (hits, misses) = simulate_lru(&col_plan, STRIP_ROWS, 1);
    assert_eq!(hits, 0);
    let (reads, _, _) = read_amplification(&col_plan, STRIP_ROWS);
    assert_eq!(misses, reads as u64);
}

#[test]
fn concurrent_readers_see_consistent_bytes_on_both_backings() {
    // Smaller image: this test reads every block from 4 threads.
    let img = SyntheticOrtho::default().with_seed(17).generate(96, 77);
    let plan = BlockPlan::new(96, 77, BlockShape::Square { side: 13 });
    for backing in backings("concurrent") {
        let file_backed = matches!(backing, Backing::File(_));
        let store = Arc::new(StripStore::new(&img, 5, backing).unwrap());
        let img = Arc::new(img.clone());
        let mut handles = Vec::new();
        for t in 0..4 {
            let store = Arc::clone(&store);
            let img = Arc::clone(&img);
            let plan = BlockPlan::new(96, 77, BlockShape::Square { side: 13 });
            handles.push(std::thread::spawn(move || {
                let mut reader = store.reader().unwrap();
                let mut buf = Vec::new();
                for region in plan.iter() {
                    reader.read_block(region, &mut buf).unwrap();
                    assert_eq!(
                        buf,
                        img.crop(region),
                        "thread {t}: inconsistent bytes at {region}"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 4 threads × full pass, counters exact
        let snap = store.stats().snapshot();
        assert_eq!(
            snap.block_reads as usize,
            plan.len() * 4,
            "file_backed={file_backed}"
        );
        let (per_pass, _, _) = read_amplification(&plan, 5);
        assert_eq!(snap.strip_reads as usize, per_pass * 4);
    }
}
