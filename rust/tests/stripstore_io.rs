//! Strip-store I/O contract tests.
//!
//! Three properties, on BOTH backings (memory and real file):
//!
//! 1. **Counted = closed form.** The `AccessStats` strip-read counter
//!    after one full pass over a plan equals the closed-form
//!    `read_amplification` prediction, for the paper's three block
//!    shapes (row / column / square, §4 Cases 1–3 scaled down 1:10).
//! 2. **Concurrent readers see consistent bytes.** Several
//!    `StripReader`s racing over the same store each reconstruct every
//!    block bit-identical to a direct crop of the source raster.
//! 3. **Cache hits/misses = closed form.** With the shared strip cache
//!    enabled, the hit/miss counters after a single-reader pass equal
//!    an exact LRU simulation of the plan's strip access sequence —
//!    row plans get no reuse, column plans hit on every revisit.

use std::sync::Arc;

use blockms::blocks::{BlockPlan, BlockShape};
use blockms::image::{Raster, SyntheticOrtho};
use blockms::stripstore::{read_amplification, Backing, StripStore};

/// The paper's 4656×5793 hero image scaled ~1:10 (width 466, height 579).
const H: usize = 579;
const W: usize = 466;
const STRIP_ROWS: usize = 8;

fn hero_image() -> Raster {
    SyntheticOrtho::default().with_seed(41).generate(H, W)
}

/// Row / square / column shapes at 1:10 of the paper's Cases 1–3.
fn paper_shapes() -> [(&'static str, BlockShape); 3] {
    [
        ("row", BlockShape::Custom { rows: 120, cols: W }),
        ("square", BlockShape::Square { side: 120 }),
        ("column", BlockShape::Custom { rows: H, cols: 100 }),
    ]
}

fn backings(tag: &str) -> [Backing; 2] {
    [
        Backing::Memory,
        Backing::File(std::env::temp_dir().join(format!("blockms_striptest_{tag}"))),
    ]
}

#[test]
fn counted_reads_equal_closed_form_on_both_backings() {
    let img = hero_image();
    for (name, shape) in paper_shapes() {
        let plan = BlockPlan::new(H, W, shape);
        let (expected_reads, total_strips, amp) = read_amplification(&plan, STRIP_ROWS);
        assert!(total_strips > 0 && amp >= 1.0);
        for backing in backings(name) {
            let file_backed = matches!(backing, Backing::File(_));
            let store = StripStore::new(&img, STRIP_ROWS, backing).unwrap();
            let mut reader = store.reader().unwrap();
            let mut buf = Vec::new();
            for region in plan.iter() {
                reader.read_block(region, &mut buf).unwrap();
            }
            let snap = store.stats().snapshot();
            assert_eq!(
                snap.strip_reads as usize, expected_reads,
                "{name} (file_backed={file_backed}): counted != closed form"
            );
            assert_eq!(snap.block_reads as usize, plan.len());
            assert!(snap.bytes_read > 0);
        }
    }
}

/// The column case is the paper's worst case: ⌈466/100⌉ = 5 column
/// blocks, each spanning every strip → the whole file is read exactly
/// 5×. The row case is the best case: amplification 1 (strip-aligned
/// bands).
#[test]
fn paper_case_amplifications_at_one_tenth_scale() {
    let col_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: H, cols: 100 });
    let (_, _, col_amp) = read_amplification(&col_plan, STRIP_ROWS);
    assert_eq!(col_amp, 5.0, "column blocks must read the file 5x");

    let row_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: 120, cols: W });
    let (_, _, row_amp) = read_amplification(&row_plan, STRIP_ROWS);
    assert!(row_amp < 1.01, "row blocks must approach amplification 1, got {row_amp}");

    let sq_plan = BlockPlan::new(H, W, BlockShape::Square { side: 120 });
    let (_, _, sq_amp) = read_amplification(&sq_plan, STRIP_ROWS);
    // 466/120 → 4 blocks per strip row: every strip read ~4x
    assert!((sq_amp - 4.0).abs() < 0.05, "square amplification {sq_amp}");
}

/// Exact LRU simulation of a single reader visiting `plan`'s blocks in
/// order (strips ascending within each block): the closed form the
/// counted hit/miss numbers must equal.
fn simulate_lru(plan: &BlockPlan, strip_rows: usize, cap: usize) -> (u64, u64) {
    simulate_lru_passes(plan, strip_rows, cap, 1)
}

/// The same LRU simulation over `passes` consecutive full passes of the
/// plan through one cache — the access sequence of an N-variant
/// same-image sweep (N jobs × (iters+1) block passes, one shared
/// store). At full capacity the counts are interleaving-invariant, so
/// this matches any co-schedule order the server picks.
fn simulate_lru_passes(
    plan: &BlockPlan,
    strip_rows: usize,
    cap: usize,
    passes: usize,
) -> (u64, u64) {
    let (mut hits, mut misses) = (0u64, 0u64);
    let mut tick = 0u64;
    let mut resident: Vec<(usize, u64)> = Vec::new(); // (strip, last_used)
    for _ in 0..passes {
        for b in plan.iter() {
            let first = b.row0 / strip_rows;
            let last = (b.row_end() - 1) / strip_rows;
            for s in first..=last {
                tick += 1;
                if let Some(e) = resident.iter_mut().find(|(st, _)| *st == s) {
                    e.1 = tick;
                    hits += 1;
                } else {
                    misses += 1;
                    resident.push((s, tick));
                    if resident.len() > cap {
                        let lru = resident
                            .iter()
                            .enumerate()
                            .min_by_key(|(_, (_, used))| *used)
                            .map(|(i, _)| i)
                            .unwrap();
                        resident.remove(lru);
                    }
                }
            }
        }
    }
    (hits, misses)
}

#[test]
fn cache_hit_miss_counts_equal_closed_form_on_both_backings() {
    let img = hero_image();
    let total_strips = H.div_ceil(STRIP_ROWS);
    // Full capacity and a deliberately thrashing capacity.
    for cap in [total_strips, 3] {
        for (name, shape) in paper_shapes() {
            let plan = BlockPlan::new(H, W, shape);
            let (want_hits, want_misses) = simulate_lru(&plan, STRIP_ROWS, cap);
            for backing in backings(&format!("cache_{name}_{cap}")) {
                let file_backed = matches!(backing, Backing::File(_));
                let mut store = StripStore::new(&img, STRIP_ROWS, backing).unwrap();
                store.enable_cache(cap);
                let mut reader = store.reader().unwrap();
                let mut buf = Vec::new();
                for region in plan.iter() {
                    reader.read_block(region, &mut buf).unwrap();
                    assert_eq!(buf, img.crop(region), "{name}: cached bytes differ");
                }
                let snap = store.stats().snapshot();
                assert_eq!(
                    (snap.strip_cache_hits, snap.strip_cache_misses),
                    (want_hits, want_misses),
                    "{name} cap={cap} (file_backed={file_backed})"
                );
                // Only misses transfer from the backing.
                assert_eq!(snap.strip_reads, want_misses, "{name} cap={cap}");
            }
        }
    }
}

/// The paper-shape headline numbers at full cache capacity: the column
/// plan's 5× re-read collapses to hits, the (strip-aligned) row plan
/// has nothing to reuse, and the square plan hits on 3 of its 4 visits
/// to each strip.
#[test]
fn column_plans_reuse_square_partially_rows_never() {
    let total_strips = H.div_ceil(STRIP_ROWS) as u64;

    let col_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: H, cols: 100 });
    let (hits, misses) = simulate_lru(&col_plan, STRIP_ROWS, total_strips as usize);
    assert_eq!(misses, total_strips);
    assert_eq!(hits, 4 * total_strips, "5 column blocks -> 4 revisits");

    let row_plan = BlockPlan::new(H, W, BlockShape::Custom { rows: 120, cols: W });
    let (hits, _) = simulate_lru(&row_plan, STRIP_ROWS, total_strips as usize);
    assert_eq!(hits, 0, "strip-aligned rows have no reuse");

    let sq_plan = BlockPlan::new(H, W, BlockShape::Square { side: 120 });
    let (hits, misses) = simulate_lru(&sq_plan, STRIP_ROWS, total_strips as usize);
    assert_eq!(misses, total_strips);
    let (reads, _, _) = read_amplification(&sq_plan, STRIP_ROWS);
    assert_eq!(hits, reads as u64 - total_strips);

    // With a single-strip cache, the column plan's stride-through access
    // pattern defeats LRU entirely: every access misses.
    let (hits, misses) = simulate_lru(&col_plan, STRIP_ROWS, 1);
    assert_eq!(hits, 0);
    let (reads, _, _) = read_amplification(&col_plan, STRIP_ROWS);
    assert_eq!(misses, reads as u64);
}

#[test]
fn concurrent_readers_see_consistent_bytes_on_both_backings() {
    // Smaller image: this test reads every block from 4 threads.
    let img = SyntheticOrtho::default().with_seed(17).generate(96, 77);
    let plan = BlockPlan::new(96, 77, BlockShape::Square { side: 13 });
    for backing in backings("concurrent") {
        let file_backed = matches!(backing, Backing::File(_));
        let store = Arc::new(StripStore::new(&img, 5, backing).unwrap());
        let img = Arc::new(img.clone());
        let mut handles = Vec::new();
        for t in 0..4 {
            let store = Arc::clone(&store);
            let img = Arc::clone(&img);
            let plan = BlockPlan::new(96, 77, BlockShape::Square { side: 13 });
            handles.push(std::thread::spawn(move || {
                let mut reader = store.reader().unwrap();
                let mut buf = Vec::new();
                for region in plan.iter() {
                    reader.read_block(region, &mut buf).unwrap();
                    assert_eq!(
                        buf,
                        img.crop(region),
                        "thread {t}: inconsistent bytes at {region}"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 4 threads × full pass, counters exact
        let snap = store.stats().snapshot();
        assert_eq!(
            snap.block_reads as usize,
            plan.len() * 4,
            "file_backed={file_backed}"
        );
        let (per_pass, _, _) = read_amplification(&plan, 5);
        assert_eq!(snap.strip_reads as usize, per_pass * 4);
    }
}

// ---------------------------------------------------------------------
// Sweep share groups: one decode per strip for same-image variants
// ---------------------------------------------------------------------

use blockms::coordinator::ClusterConfig;
use blockms::plan::ExecPlan;
use blockms::service::{ClusterServer, ServerConfig};
use blockms::stripstore::AccessSnapshot;
use blockms::sweep::{collect_outputs, submit_sweep, SweepGrid};

/// Sweep-test geometry: small image, strip-aligned full cache.
const SH: usize = 64;
const SW: usize = 48;
const SROWS: usize = 8;

fn sweep_image(seed: u64) -> Arc<Raster> {
    Arc::new(SyntheticOrtho::default().with_seed(seed).generate(SH, SW))
}

/// Group-total counters: every member snapshots the one shared store's
/// monotone counters, so the per-field max over member snapshots is the
/// last finalizer's view — the sweep total.
fn group_totals(snaps: &[AccessSnapshot]) -> (u64, u64, u64, u64) {
    (
        snaps.iter().map(|s| s.strip_reads).max().unwrap(),
        snaps.iter().map(|s| s.bytes_read).max().unwrap(),
        snaps.iter().map(|s| s.strip_cache_hits).max().unwrap(),
        snaps.iter().map(|s| s.strip_cache_misses).max().unwrap(),
    )
}

/// An N-variant same-image share group decodes each strip **once**:
/// misses = total strips, bytes_read = one image's bytes, and every
/// other access — including every later variant's whole pass structure
/// — is a cache hit, exactly matching the LRU simulation of
/// N × (iters+1) plan passes. A single worker keeps the check-then-act
/// cache counters exact (no racing double-miss).
#[test]
fn shared_sweep_decodes_each_strip_once_on_both_backings() {
    let img = sweep_image(23);
    let image_bytes = (SH * SW * img.channels() * 4) as u64;
    let iters = 2usize;
    let grid = SweepGrid::from_args("2..4", 5, 1, "random").unwrap(); // 3 variants
    let total_strips = SH.div_ceil(SROWS);

    let shape = BlockShape::Square { side: 16 };
    let plan = BlockPlan::new(SH, SW, shape);
    let (per_pass, strips, _) = read_amplification(&plan, SROWS);
    assert_eq!(strips, total_strips);
    // Each job makes `iters` Step passes plus the final Assign pass.
    let passes = grid.len() * (iters + 1);
    let (want_hits, want_misses) = simulate_lru_passes(&plan, SROWS, total_strips, passes);
    assert_eq!(want_misses, total_strips as u64, "full cache: first pass misses only");
    assert_eq!(want_hits, (per_pass * passes) as u64 - total_strips as u64);

    for file_backed in [false, true] {
        let exec = ExecPlan::pinned(shape)
            .with_workers(1)
            .with_strip_cache(total_strips)
            .with_file_backing(file_backed);
        let base = ClusterConfig {
            fixed_iters: Some(iters),
            ..ClusterConfig::default()
        };
        let server = ClusterServer::start(ServerConfig {
            workers: 1,
            max_in_flight: grid.len(),
            ..ServerConfig::default()
        });
        let handles = submit_sweep(&server, &img, exec, &base, &grid, SROWS, Some(1)).unwrap();
        let outs = collect_outputs(&handles).unwrap();
        server.shutdown();

        let snaps: Vec<AccessSnapshot> = outs.iter().filter_map(|o| o.io_stats).collect();
        assert_eq!(snaps.len(), grid.len(), "every variant reports I/O");
        let (strip_reads, bytes, hits, misses) = group_totals(&snaps);
        assert_eq!(
            misses, total_strips as u64,
            "file_backed={file_backed}: each strip decodes exactly once for the whole sweep"
        );
        assert_eq!(strip_reads, total_strips as u64, "file_backed={file_backed}");
        assert_eq!(
            bytes, image_bytes,
            "file_backed={file_backed}: one image's bytes for {} variants",
            grid.len()
        );
        assert_eq!(hits, want_hits, "file_backed={file_backed}: hits match LRU simulation");
    }
}

/// The serialized contrast: the same grid submitted *without* a share
/// group gives every variant its own store — each decodes the full
/// image, so the sweep reads N× the bytes the shared group reads.
#[test]
fn unshared_sweep_multiplies_bytes_by_variant_count() {
    let img = sweep_image(23);
    let image_bytes = (SH * SW * img.channels() * 4) as u64;
    let grid = SweepGrid::from_args("2..4", 5, 1, "random").unwrap();
    let total_strips = SH.div_ceil(SROWS);
    let exec = ExecPlan::pinned(BlockShape::Square { side: 16 })
        .with_workers(1)
        .with_strip_cache(total_strips);
    let base = ClusterConfig {
        fixed_iters: Some(2),
        ..ClusterConfig::default()
    };
    let server = ClusterServer::start(ServerConfig {
        workers: 1,
        max_in_flight: grid.len(),
        ..ServerConfig::default()
    });
    let handles = submit_sweep(&server, &img, exec, &base, &grid, SROWS, None).unwrap();
    let outs = collect_outputs(&handles).unwrap();
    server.shutdown();

    let mut sum_bytes = 0u64;
    for out in &outs {
        let snap = out.io_stats.expect("private store counters");
        assert_eq!(snap.bytes_read, image_bytes, "each isolated job decodes the whole image");
        assert_eq!(snap.strip_cache_misses, total_strips as u64);
        sum_bytes += snap.bytes_read;
    }
    assert_eq!(sum_bytes, grid.len() as u64 * image_bytes, "serialized sweep = N× the shared bytes");
}

/// Two share groups over two *different* images on one server stay
/// fully isolated: each group's store decodes exactly its own image's
/// bytes — tiles and strips never cross-share between images.
#[test]
fn mixed_image_sweeps_do_not_cross_share() {
    let img_a = sweep_image(23);
    let (bh, bw) = (40, 32); // different geometry so the byte totals can't alias
    let img_b = Arc::new(SyntheticOrtho::default().with_seed(29).generate(bh, bw));
    let bytes_a = (SH * SW * img_a.channels() * 4) as u64;
    let bytes_b = (bh * bw * img_b.channels() * 4) as u64;
    assert_ne!(bytes_a, bytes_b);

    let grid = SweepGrid::from_args("2..3", 7, 1, "random").unwrap(); // 2 variants per image
    let base = ClusterConfig {
        fixed_iters: Some(2),
        ..ClusterConfig::default()
    };
    let server = ClusterServer::start(ServerConfig {
        workers: 1,
        max_in_flight: 2 * grid.len(),
        ..ServerConfig::default()
    });
    let exec_a = ExecPlan::pinned(BlockShape::Square { side: 16 })
        .with_workers(1)
        .with_strip_cache(SH.div_ceil(SROWS));
    let exec_b = ExecPlan::pinned(BlockShape::Square { side: 16 })
        .with_workers(1)
        .with_strip_cache(bh.div_ceil(SROWS));
    let handles_a = submit_sweep(&server, &img_a, exec_a, &base, &grid, SROWS, Some(1)).unwrap();
    let handles_b = submit_sweep(&server, &img_b, exec_b, &base, &grid, SROWS, Some(2)).unwrap();
    let outs_a = collect_outputs(&handles_a).unwrap();
    let outs_b = collect_outputs(&handles_b).unwrap();
    server.shutdown();

    let snaps_a: Vec<AccessSnapshot> = outs_a.iter().filter_map(|o| o.io_stats).collect();
    let snaps_b: Vec<AccessSnapshot> = outs_b.iter().filter_map(|o| o.io_stats).collect();
    let (_, group_a_bytes, _, a_misses) = group_totals(&snaps_a);
    let (_, group_b_bytes, _, b_misses) = group_totals(&snaps_b);
    assert_eq!(group_a_bytes, bytes_a, "group A decodes exactly image A");
    assert_eq!(group_b_bytes, bytes_b, "group B decodes exactly image B");
    assert_eq!(a_misses, SH.div_ceil(SROWS) as u64);
    assert_eq!(b_misses, bh.div_ceil(SROWS) as u64);
}

/// Joining a live share group with a *different* image is an activation
/// error, not a silent un-share: shared tiles over different pixels
/// would corrupt results, so the server must refuse the member.
#[test]
fn share_group_rejects_a_different_image() {
    // Same dimensions on purpose: the rejection must come from image
    // identity (Arc::ptr_eq), not from any shape mismatch.
    let img_a = sweep_image(23);
    let img_b = sweep_image(31);
    // Enough fixed rounds that variant A is still live (group alive)
    // when B is admitted — admission is two queued messages behind A's
    // multi-millisecond run.
    let grid_a = SweepGrid::from_args("8", 5, 1, "random").unwrap();
    let grid_b = SweepGrid::from_args("2", 5, 1, "random").unwrap();
    let base = ClusterConfig {
        fixed_iters: Some(30),
        ..ClusterConfig::default()
    };
    let exec = ExecPlan::pinned(BlockShape::Square { side: 16 })
        .with_workers(1)
        .with_strip_cache(SH.div_ceil(SROWS));
    let server = ClusterServer::start(ServerConfig {
        workers: 1,
        max_in_flight: 2,
        ..ServerConfig::default()
    });
    let handles_a = submit_sweep(&server, &img_a, exec, &base, &grid_a, SROWS, Some(9)).unwrap();
    let handles_b = submit_sweep(&server, &img_b, exec, &base, &grid_b, SROWS, Some(9)).unwrap();
    let err = handles_b[0]
        .wait_output()
        .expect_err("different image must not join the group");
    assert!(
        format!("{err:#}").contains("share-group"),
        "error must name the share-group violation: {err:#}"
    );
    // The original member is unharmed.
    let out = handles_a[0].wait_output().unwrap();
    assert_eq!(out.labels.len(), SH * SW);
    server.shutdown();
}
