//! Sweep-vs-solo bit-identity matrix.
//!
//! The amortized sweep's whole contract: every variant of a share-group
//! sweep must be **bit-identical** — labels, centroids, per-cluster
//! counts, inertia — to the same `(k, seed, init)` job run alone.
//! Sharing changes where bytes come from (one store, one decode, shared
//! tiles), never the arithmetic. The matrix crosses the paper's kernel
//! variants with the three block shapes and both store backings; a
//! qcheck property fuzzes random `(k, seed)` grids on top.

use std::sync::Arc;

use blockms::blocks::BlockShape;
use blockms::coordinator::{
    ClusterConfig, ClusterOutput, Coordinator, CoordinatorConfig, IoMode,
};
use blockms::image::{Raster, SyntheticOrtho};
use blockms::kmeans::kernel::KernelChoice;
use blockms::kmeans::InitMethod;
use blockms::plan::ExecPlan;
use blockms::service::{ClusterServer, ServerConfig};
use blockms::sweep::{collect_outputs, submit_sweep, SweepGrid};
use blockms::util::qcheck::{forall, pair, usize_in};

const H: usize = 48;
const W: usize = 40;
const STRIP_ROWS: usize = 8;

fn image() -> Arc<Raster> {
    Arc::new(SyntheticOrtho::default().with_seed(33).generate(H, W))
}

/// Per-cluster pixel counts — `labels` equality implies these match,
/// but the sweep contract names counts explicitly, so check them
/// explicitly.
fn counts(labels: &[u32], k: usize) -> Vec<u64> {
    let mut c = vec![0u64; k];
    for &l in labels {
        c[l as usize] += 1;
    }
    c
}

/// The independent solo twin: a fresh single-job [`Coordinator`] with
/// the identical plan, I/O mode and clustering config — no server, no
/// share group, nothing in common but the arithmetic.
fn solo(
    img: &Arc<Raster>,
    exec: ExecPlan,
    cfg: &ClusterConfig,
    strip_rows: usize,
    file_backed: bool,
) -> ClusterOutput {
    let coord = Coordinator::new(CoordinatorConfig {
        exec,
        io: IoMode::Strips {
            strip_rows,
            file_backed,
        },
        ..CoordinatorConfig::default()
    });
    coord.cluster(img, cfg).unwrap()
}

/// Bitwise identity on every observable the sweep reports.
fn assert_identical(sweep: &ClusterOutput, twin: &ClusterOutput, k: usize, ctx: &str) {
    assert_eq!(sweep.labels, twin.labels, "{ctx}: labels diverged");
    let sweep_bits: Vec<u32> = sweep.centroids.iter().map(|c| c.to_bits()).collect();
    let twin_bits: Vec<u32> = twin.centroids.iter().map(|c| c.to_bits()).collect();
    assert_eq!(sweep_bits, twin_bits, "{ctx}: centroid bits diverged");
    assert_eq!(
        counts(&sweep.labels, k),
        counts(&twin.labels, k),
        "{ctx}: cluster counts diverged"
    );
    assert_eq!(
        sweep.inertia.to_bits(),
        twin.inertia.to_bits(),
        "{ctx}: inertia bits diverged"
    );
    assert_eq!(sweep.iterations, twin.iterations, "{ctx}: iteration count");
}

/// The full matrix: naive / pruned / lanes kernels × row / column /
/// square blocks × memory / file backings, each cell sweeping a
/// 2-k × 2-init grid and checking every variant against its solo twin.
#[test]
fn sweep_variants_bit_identical_to_solo_across_the_matrix() {
    let img = image();
    let grid = SweepGrid::from_args("2..3", 9, 1, "random,plusplus").unwrap();
    assert_eq!(grid.len(), 4);
    let base = ClusterConfig {
        fixed_iters: Some(3),
        ..ClusterConfig::default()
    };
    for kernel in [KernelChoice::Naive, KernelChoice::Pruned, KernelChoice::Lanes] {
        for (sname, shape) in [
            ("row", BlockShape::Rows { band_rows: 16 }),
            ("column", BlockShape::Cols { band_cols: 14 }),
            ("square", BlockShape::Square { side: 16 }),
        ] {
            for file_backed in [false, true] {
                let cell = format!("{kernel:?}/{sname}/file={file_backed}");
                let exec = ExecPlan::pinned(shape)
                    .with_kernel(kernel)
                    .with_workers(2)
                    .with_strip_cache(H.div_ceil(STRIP_ROWS))
                    .with_file_backing(file_backed);
                let server = ClusterServer::start(ServerConfig {
                    workers: 2,
                    max_in_flight: grid.len(),
                    ..ServerConfig::default()
                });
                let handles =
                    submit_sweep(&server, &img, exec, &base, &grid, STRIP_ROWS, Some(1))
                        .unwrap();
                let outs = collect_outputs(&handles).unwrap();
                server.shutdown();
                for (v, out) in grid.expand().iter().zip(&outs) {
                    let mut cfg = base.clone();
                    cfg.k = v.k;
                    cfg.seed = v.seed;
                    cfg.init = v.init.clone();
                    let twin = solo(&img, exec, &cfg, STRIP_ROWS, file_backed);
                    assert_identical(out, &twin, v.k, &format!("{cell}/{}", v.label()));
                }
            }
        }
    }
}

/// qcheck: random `(k, seed)` grids — two ks × two seeds per case —
/// stay bit-identical to their solo twins under the default pinned
/// plan. Catches anything the hand-picked matrix geometry misses.
#[test]
fn random_k_seed_grids_stay_bit_identical_to_solo() {
    let img = Arc::new(SyntheticOrtho::default().with_seed(51).generate(32, 28));
    let base = ClusterConfig {
        fixed_iters: Some(2),
        ..ClusterConfig::default()
    };
    let gen = pair(usize_in(2, 5), usize_in(0, 1 << 16));
    forall(16, 8, &gen, |&(k, seed)| {
        let grid = SweepGrid::new(
            vec![k, k + 1],
            vec![seed as u64, seed as u64 + 1],
            vec![InitMethod::RandomSample],
        )
        .unwrap();
        let exec = ExecPlan::pinned(BlockShape::Square { side: 12 })
            .with_workers(2)
            .with_strip_cache(32usize.div_ceil(STRIP_ROWS));
        let server = ClusterServer::start(ServerConfig {
            workers: 2,
            max_in_flight: grid.len(),
            ..ServerConfig::default()
        });
        let handles = submit_sweep(&server, &img, exec, &base, &grid, STRIP_ROWS, Some(1))
            .expect("submit random grid");
        let outs = collect_outputs(&handles).expect("collect random grid");
        server.shutdown();
        grid.expand().iter().zip(&outs).all(|(v, out)| {
            let mut cfg = base.clone();
            cfg.k = v.k;
            cfg.seed = v.seed;
            cfg.init = v.init.clone();
            let twin = solo(&img, exec, &cfg, STRIP_ROWS, false);
            out.labels == twin.labels
                && out
                    .centroids
                    .iter()
                    .map(|c| c.to_bits())
                    .eq(twin.centroids.iter().map(|c| c.to_bits()))
                && out.inertia.to_bits() == twin.inertia.to_bits()
        })
    });
}
