//! Full-pipeline integration tests: coordinator × engines × I/O modes ×
//! clustering modes, plus failure handling and config-file driving.

use std::sync::Arc;

use blockms::blocks::{ApproachKind, BlockShape};
use blockms::coordinator::{
    ClusterConfig, ClusterMode, Coordinator, CoordinatorConfig, Engine, IoMode, Schedule,
};
use blockms::image::{Raster, SyntheticOrtho};
use blockms::kmeans::InitMethod;
use blockms::plan::ExecPlan;
use blockms::resilience::{FaultKind, FaultPlan};
use blockms::runtime::find_artifacts_dir;
use blockms::util::config::Config;

fn scene(h: usize, w: usize, seed: u64) -> Arc<Raster> {
    Arc::new(SyntheticOrtho::default().with_seed(seed).generate(h, w))
}

#[test]
fn full_matrix_native_modes_shapes_workers() {
    let img = scene(72, 60, 1);
    for mode in [ClusterMode::Global, ClusterMode::Local] {
        for kind in ApproachKind::ALL {
            for workers in [1usize, 3] {
                let shape = BlockShape::paper_default(kind, 72, 60);
                let coord = Coordinator::new(CoordinatorConfig {
                    exec: ExecPlan::pinned(shape).with_workers(workers),
                    mode,
                    ..Default::default()
                });
                let out = coord
                    .cluster(&img, &ClusterConfig { k: 4, ..Default::default() })
                    .unwrap();
                assert_eq!(out.labels.len(), 72 * 60, "{mode:?}/{kind:?}/{workers}");
                assert!(out.labels.iter().all(|&l| l < 4));
                assert!(out.inertia > 0.0);
                assert_eq!(out.centroids.len(), 4 * 3);
            }
        }
    }
}

#[test]
fn inertia_trace_is_monotone_nonincreasing() {
    let img = scene(64, 64, 2);
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 20 }),
        ..Default::default()
    });
    let out = coord
        .cluster(
            &img,
            &ClusterConfig {
                k: 4,
                fixed_iters: Some(8),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(out.inertia_trace.len(), 8);
    for pair in out.inertia_trace.windows(2) {
        assert!(
            pair[1] <= pair[0] * (1.0 + 1e-9) + 1e-6,
            "inertia rose: {pair:?}"
        );
    }
}

#[test]
fn schedules_agree_on_results() {
    let img = scene(50, 70, 3);
    let cfg = ClusterConfig {
        k: 2,
        ..Default::default()
    };
    let mut outs = Vec::new();
    for schedule in [Schedule::Static, Schedule::Dynamic] {
        let coord = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(BlockShape::Cols { band_cols: 13 }).with_workers(3),
            schedule,
            ..Default::default()
        });
        outs.push(coord.cluster(&img, &cfg).unwrap());
    }
    assert_eq!(outs[0].labels, outs[1].labels);
    assert_eq!(outs[0].centroids, outs[1].centroids);
}

#[test]
fn file_backed_strips_agree_with_direct() {
    let img = scene(40, 56, 4);
    let exec = ExecPlan::pinned(BlockShape::Rows { band_rows: 11 });
    let cfg = ClusterConfig {
        k: 2,
        ..Default::default()
    };
    let direct = Coordinator::new(CoordinatorConfig {
        exec,
        ..Default::default()
    })
    .cluster(&img, &cfg)
    .unwrap();
    let strips = Coordinator::new(CoordinatorConfig {
        exec,
        io: IoMode::Strips {
            strip_rows: 7,
            file_backed: true,
        },
        ..Default::default()
    })
    .cluster(&img, &cfg)
    .unwrap();
    assert_eq!(direct.labels, strips.labels);
    assert_eq!(direct.centroids, strips.centroids);
    let io = strips.io_stats.unwrap();
    assert!(io.bytes_read > 0);
}

#[test]
fn init_methods_all_work_and_are_deterministic() {
    let img = scene(40, 40, 5);
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 16 }),
        ..Default::default()
    });
    for init in [
        InitMethod::RandomSample,
        InitMethod::PlusPlus,
        InitMethod::Fixed(vec![10.0, 10.0, 10.0, 200.0, 200.0, 200.0]),
    ] {
        let cfg = ClusterConfig {
            k: 2,
            init: init.clone(),
            ..Default::default()
        };
        let a = coord.cluster(&img, &cfg).unwrap();
        let b = coord.cluster(&img, &cfg).unwrap();
        assert_eq!(a.labels, b.labels, "{init:?} not deterministic");
    }
}

#[test]
fn failure_in_later_round_still_propagates() {
    let img = scene(40, 40, 6);
    // fail a block that exists (plan has 9 blocks; index 8 processed in
    // every round including assign)
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 13 }).with_workers(2),
        fault: Some(FaultPlan::always(8, FaultKind::Error)),
        ..Default::default()
    });
    let err = coord.cluster(&img, &ClusterConfig::default()).unwrap_err();
    assert!(err.to_string().contains("injected failure"));
}

#[test]
fn k_larger_than_block_pixels_is_handled() {
    // a 1x1-block plan with k=4: blocks have fewer pixels than k — the
    // global reduction still works (per-block partial sums are fine)
    let img = scene(6, 6, 7);
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 1 }).with_workers(2),
        ..Default::default()
    });
    let out = coord
        .cluster(
            &img,
            &ClusterConfig {
                k: 4,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(out.labels.len(), 36);
    let seq = coord
        .serial(
            &img,
            &ClusterConfig {
                k: 4,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(out.labels, seq.labels);
}

#[test]
fn config_file_drives_a_run() {
    let src = "
[workload]
width = 64
height = 48
seed = 11

[cluster]
k = 4
max_iters = 5

[run]
workers = 3
";
    let cfg = Config::parse(src).unwrap();
    let img = scene(
        cfg.get_parse::<usize>("workload.height").unwrap().unwrap(),
        cfg.get_parse::<usize>("workload.width").unwrap().unwrap(),
        cfg.get_parse::<u64>("workload.seed").unwrap().unwrap(),
    );
    let shape = BlockShape::paper_default(ApproachKind::Cols, img.height(), img.width());
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(shape).with_workers(cfg.get_or("run.workers", 1).unwrap()),
        ..Default::default()
    });
    let out = coord
        .cluster(
            &img,
            &ClusterConfig {
                k: cfg.get_or("cluster.k", 2).unwrap(),
                max_iters: cfg.get_or("cluster.max_iters", 20).unwrap(),
                seed: cfg.get_or("workload.seed", 0).unwrap(),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(out.labels.len(), 48 * 64);
    assert!(out.iterations <= 5);
}

// ---------------------------------------------------------------------------
// Out-of-core streaming: bit-identity and the resident-byte budget
// ---------------------------------------------------------------------------

fn label_counts(labels: &[u32], k: usize) -> Vec<u64> {
    let mut counts = vec![0u64; k];
    for &l in labels {
        counts[l as usize] += 1;
    }
    counts
}

/// The tentpole invariant: a `RasterSource`-fed run equals the
/// `Arc<Raster>` run EXACTLY — labels, centroids, counts, inertia —
/// across the paper block shapes × kernels × both store backings.
#[test]
fn streamed_runs_are_bit_identical_to_in_memory_runs() {
    use blockms::image::SyntheticSource;
    use blockms::kmeans::KernelChoice;

    let (h, w, k) = (60usize, 48usize, 3usize);
    let gen = SyntheticOrtho::default().with_seed(12);
    let img = scene_from(&gen, h, w);
    let ccfg = ClusterConfig {
        k,
        seed: 5,
        ..Default::default()
    };
    for kind in ApproachKind::ALL {
        let shape = BlockShape::paper_default(kind, h, w);
        for kernel in [KernelChoice::Naive, KernelChoice::Pruned, KernelChoice::Lanes] {
            for file_backed in [false, true] {
                let exec = ExecPlan::pinned(shape).with_workers(3).with_kernel(kernel);
                let io = IoMode::Strips {
                    strip_rows: 8,
                    file_backed,
                };
                let tag = format!("{kind:?}/{kernel}/file={file_backed}");
                let mem = Coordinator::new(CoordinatorConfig {
                    exec,
                    io: io.clone(),
                    ..Default::default()
                })
                .cluster(&img, &ccfg)
                .unwrap();
                let coord = Coordinator::new(CoordinatorConfig {
                    exec,
                    io,
                    ..Default::default()
                });
                let mut src = SyntheticSource::new(&gen, h, w);
                let run = coord.cluster_source(&mut src, &ccfg).unwrap();
                assert_eq!(run.centroids, mem.centroids, "{tag}: centroids");
                assert_eq!(run.iterations, mem.iterations, "{tag}: iterations");
                assert_eq!(run.converged, mem.converged, "{tag}: convergence");
                assert_eq!(
                    run.inertia.to_bits(),
                    mem.inertia.to_bits(),
                    "{tag}: inertia"
                );
                assert_eq!(run.inertia_trace, mem.inertia_trace, "{tag}: trace");
                let streamed_labels = run.labels.into_dense().unwrap();
                assert_eq!(streamed_labels, mem.labels, "{tag}: labels");
                assert_eq!(
                    label_counts(&streamed_labels, k),
                    label_counts(&mem.labels, k),
                    "{tag}: counts"
                );
            }
        }
    }
}

fn scene_from(gen: &SyntheticOrtho, h: usize, w: usize) -> Arc<Raster> {
    Arc::new(gen.generate(h, w))
}

#[test]
fn streamed_ppm_matches_in_memory_read_of_the_same_file() {
    let img = scene(40, 36, 13);
    let dir = std::env::temp_dir().join("blockms_integration_stream");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scene.ppm");
    blockms::image::write_ppm(&img, &path).unwrap();
    let twin = Arc::new(blockms::image::read_ppm(&path).unwrap());

    let exec = ExecPlan::pinned(BlockShape::Square { side: 11 }).with_workers(2);
    let io = IoMode::Strips {
        strip_rows: 7,
        file_backed: true,
    };
    let ccfg = ClusterConfig {
        k: 2,
        seed: 9,
        ..Default::default()
    };
    let mem = Coordinator::new(CoordinatorConfig {
        exec,
        io: io.clone(),
        ..Default::default()
    })
    .cluster(&twin, &ccfg)
    .unwrap();
    let mut src = blockms::image::PpmSource::open(&path).unwrap();
    let run = Coordinator::new(CoordinatorConfig {
        exec,
        io,
        ..Default::default()
    })
    .cluster_source(&mut src, &ccfg)
    .unwrap();
    assert_eq!(run.labels.into_dense().unwrap(), mem.labels);
    assert_eq!(run.centroids, mem.centroids);
}

/// The accounting invariant: a tall image streams under the configured
/// budget, the peak is audited (not modeled), and it does not grow with
/// image height.
#[test]
fn tall_streamed_image_peak_resident_is_budget_bounded() {
    use blockms::image::SyntheticSource;

    let run_at = |height: usize| {
        let gen = SyntheticOrtho::default().with_seed(33);
        let exec = ExecPlan::pinned(BlockShape::Rows { band_rows: 16 })
            .with_workers(2)
            .with_mem_mb(1)
            .with_file_backing(true);
        let coord = Coordinator::new(CoordinatorConfig {
            exec,
            io: IoMode::Strips {
                strip_rows: 16,
                file_backed: true,
            },
            ..Default::default()
        });
        let ccfg = ClusterConfig {
            k: 2,
            fixed_iters: Some(3),
            seed: 1,
            ..Default::default()
        };
        let mut src = SyntheticSource::new(&gen, height, 32);
        coord.cluster_source(&mut src, &ccfg).unwrap()
    };

    let short = run_at(1024);
    let tall = run_at(4096); // 4x the pixels
    let budget = 1u64 << 20;
    for (name, run, height) in [("short", &short, 1024u64), ("tall", &tall, 4096u64)] {
        let image_bytes = height * 32 * 3 * 4;
        assert!(
            run.peak_resident_bytes <= budget,
            "{name}: peak {} over the 1 MiB budget",
            run.peak_resident_bytes
        );
        assert!(
            run.peak_resident_bytes < image_bytes / 2,
            "{name}: peak {} is not out-of-core vs {image_bytes} image bytes",
            run.peak_resident_bytes
        );
        assert!(run.labels.is_spooled(), "{name}: budgeted labels must spool");
        assert_eq!(run.labels.len(), (height * 32) as usize);
    }
    assert!(
        tall.peak_resident_bytes <= short.peak_resident_bytes,
        "peak grew with height: {} -> {}",
        short.peak_resident_bytes,
        tall.peak_resident_bytes
    );
}

#[test]
fn streamed_direct_io_is_rejected() {
    use blockms::image::SyntheticSource;
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 8 }),
        ..Default::default()
    });
    let mut src = SyntheticSource::new(&SyntheticOrtho::default(), 16, 16);
    let err = coord
        .cluster_source(&mut src, &ClusterConfig::default())
        .unwrap_err();
    assert!(format!("{err:#}").contains("Strips"), "{err:#}");
}

// ---------------------------------------------------------------------------
// PJRT engine integration (skipped when artifacts are absent)
// ---------------------------------------------------------------------------

fn pjrt_available() -> bool {
    find_artifacts_dir().is_some()
}

#[test]
fn pjrt_global_agrees_with_native_to_float_tolerance() {
    if !pjrt_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let img = scene(96, 80, 8);
    let exec = ExecPlan::pinned(BlockShape::Cols { band_cols: 20 }).with_workers(2);
    let cfg = ClusterConfig {
        k: 2,
        fixed_iters: Some(4),
        ..Default::default()
    };
    let native = Coordinator::new(CoordinatorConfig {
        exec,
        ..Default::default()
    })
    .cluster(&img, &cfg)
    .unwrap();
    let pjrt = Coordinator::new(CoordinatorConfig {
        exec,
        engine: Engine::Pjrt {
            artifacts_dir: None,
        },
        ..Default::default()
    })
    .cluster(&img, &cfg)
    .unwrap();
    // identical blocks + fixed iters: labels should agree on ~all pixels
    // (f32 vs f64 partial-sum rounding can flip boundary pixels)
    let agree = native
        .labels
        .iter()
        .zip(&pjrt.labels)
        .filter(|(a, b)| a == b)
        .count() as f64
        / native.labels.len() as f64;
    assert!(agree > 0.999, "native/pjrt agreement {agree}");
    let rel = (native.inertia - pjrt.inertia).abs() / native.inertia;
    assert!(rel < 1e-3, "inertia diverged: {rel}");
}

#[test]
fn pjrt_local_mode_runs() {
    if !pjrt_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let img = scene(64, 64, 9);
    let out = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 32 }).with_workers(2),
        engine: Engine::Pjrt {
            artifacts_dir: None,
        },
        mode: ClusterMode::Local,
        ..Default::default()
    })
    .cluster(
        &img,
        &ClusterConfig {
            k: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(out.labels.len(), 64 * 64);
    assert!(out.labels.iter().all(|&l| l < 2));
}

#[test]
fn pjrt_missing_k_artifact_is_clean_error() {
    if !pjrt_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let img = scene(32, 32, 10);
    // k=5 has no artifact (ks are 2/4/8)
    let err = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Square { side: 16 }).with_workers(1),
        engine: Engine::Pjrt {
            artifacts_dir: None,
        },
        ..Default::default()
    })
    .cluster(
        &img,
        &ClusterConfig {
            k: 5,
            ..Default::default()
        },
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("k=5"), "unhelpful error: {msg}");
}

#[test]
fn stale_artifact_detected() {
    if !pjrt_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    // copy artifacts to a temp dir, tamper with one file, expect load error
    let src = find_artifacts_dir().unwrap();
    let dst = std::env::temp_dir().join("blockms_stale_artifacts");
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        std::fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
    // tamper
    let victim = dst.join("step_k2.hlo.txt");
    let mut text = std::fs::read_to_string(&victim).unwrap();
    text.push_str("\n// tampered\n");
    std::fs::write(&victim, text).unwrap();
    let err = blockms::runtime::ArtifactSet::load(&dst).unwrap_err();
    assert!(format!("{err:#}").contains("stale"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dst);
}
